"""Token-throughput ledger: per-entitlement token budgets.

The paper's admission check (4) requires that "the request's token
budget (input tokens plus max_tokens) must fit within the entitlement's
remaining throughput allocation" (§4.3).  We realise the throughput
entitlement λ_e (tokens/second) as a token bucket:

  - the bucket refills continuously at the entitlement's *effective*
    rate λ̂_e (which the pool controller adjusts: shrunk under
    contention, grown by work-conserving backfill);
  - bucket capacity is ``burst_window_s`` seconds of the rate, so short
    bursts above λ are fundable from accumulated idle credit, matching
    the paper's "burst capacity is satisfied by reallocating unused
    tokens before triggering scaling";
  - admission *charges* the nominal cost n_in + n_out_max up front and
    the completion callback *refunds* the unused portion
    (max_tokens − actual output), closing the admission/execution gap.

Deterministic; time is an explicit argument.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class TokenBucket:
    rate_tps: float                 # current refill rate λ̂_e
    burst_window_s: float = 4.0     # bucket capacity = rate · window
    level: float = 0.0              # current tokens available
    last_refill_s: float = 0.0

    def capacity(self) -> float:
        return self.rate_tps * self.burst_window_s

    def refill(self, now: float) -> None:
        dt = max(0.0, now - self.last_refill_s)
        self.level = min(self.capacity(), self.level + dt * self.rate_tps)
        self.last_refill_s = now

    def set_rate(self, rate_tps: float, now: float) -> None:
        """Adjust the refill rate (pool shrink/backfill).  Refill first so
        credit accrued at the old rate is preserved, then clamp to the
        new capacity."""
        self.refill(now)
        self.rate_tps = max(0.0, rate_tps)
        self.level = min(self.level, self.capacity())

    def can_afford(self, tokens: float, now: float) -> bool:
        self.refill(now)
        return self.level >= tokens

    def charge(self, tokens: float, now: float) -> bool:
        self.refill(now)
        if self.level < tokens:
            return False
        self.level -= tokens
        return True

    def refund(self, tokens: float, now: float) -> None:
        self.refill(now)
        self.level = min(self.capacity(), self.level + max(0.0, tokens))

    def time_until_affordable(self, tokens: float, now: float) -> float:
        """Seconds until ``tokens`` would be available — the Retry-After
        hint returned with HTTP 429 (paper §4.3)."""
        self.refill(now)
        deficit = tokens - self.level
        if deficit <= 0:
            return 0.0
        if self.rate_tps <= 0:
            return float("inf")
        return deficit / self.rate_tps


@dataclasses.dataclass
class Charge:
    """Record of an admission-time charge, so completion can refund."""

    request_id: str
    entitlement: str
    charged_tokens: float
    input_tokens: int
    max_tokens: int
    admitted_at: float


class Ledger:
    """Per-entitlement token buckets + outstanding charges."""

    def __init__(self, burst_window_s: float = 4.0) -> None:
        self._buckets: dict[str, TokenBucket] = {}
        self._charges: dict[str, Charge] = {}
        self.burst_window_s = burst_window_s

    def ensure(self, entitlement: str, rate_tps: float, now: float) -> TokenBucket:
        b = self._buckets.get(entitlement)
        if b is None:
            b = TokenBucket(rate_tps=rate_tps,
                            burst_window_s=self.burst_window_s,
                            level=rate_tps * self.burst_window_s,
                            last_refill_s=now)
            self._buckets[entitlement] = b
        return b

    def bucket(self, entitlement: str) -> TokenBucket:
        return self._buckets[entitlement]

    def peek_level(self, entitlement: str, rate_tps: float,
                   now: float) -> float:
        """Level the bucket WOULD have after a refill at ``now`` — pure
        read: no bucket is created and no refill clock advances.  For an
        entitlement with no bucket yet, this is the full initial level
        ``ensure`` would create.  Snapshotting code (the batched
        admission quantum) uses this so observing a pool never mutates
        it."""
        b = self._buckets.get(entitlement)
        if b is None:
            return rate_tps * self.burst_window_s
        dt = max(0.0, now - b.last_refill_s)
        return min(b.capacity(), b.level + dt * b.rate_tps)

    def drop(self, entitlement: str) -> None:
        """Remove an entitlement's bucket and any outstanding charges
        (entitlement teardown — the bucket must stop refilling)."""
        self._buckets.pop(entitlement, None)
        for rid in [rid for rid, ch in self._charges.items()
                    if ch.entitlement == entitlement]:
            del self._charges[rid]

    # -- migration (cross-pool entitlement rebalancing) ------------------------
    def detach(self, entitlement: str
               ) -> tuple[Optional[TokenBucket], list[Charge]]:
        """Remove and RETURN an entitlement's bucket + outstanding
        charges so they can be re-attached on another pool's ledger.
        Unlike :meth:`drop`, nothing is forgotten: the accrued bucket
        level and every admission-time charge (still owed a refund on
        completion) travel with the entitlement."""
        bucket = self._buckets.pop(entitlement, None)
        charges = [ch for ch in self._charges.values()
                   if ch.entitlement == entitlement]
        for ch in charges:
            del self._charges[ch.request_id]
        return bucket, charges

    def attach(self, entitlement: str, bucket: Optional[TokenBucket],
               charges: list[Charge], now: float) -> None:
        """Adopt a migrated bucket + charges.  The bucket keeps its
        accrued level and refill rate; only the burst window is
        re-based to THIS ledger's window (clamping the level if the
        new capacity is smaller) — the target pool's TPM semantics
        apply from the moment of the move."""
        if bucket is not None:
            bucket.refill(now)
            bucket.burst_window_s = self.burst_window_s
            bucket.level = min(bucket.level, bucket.capacity())
            self._buckets[entitlement] = bucket
        for ch in charges:
            self._charges[ch.request_id] = ch

    def set_rate(self, entitlement: str, rate_tps: float, now: float) -> None:
        self.ensure(entitlement, rate_tps, now).set_rate(rate_tps, now)

    def charge(self, charge: Charge, now: float) -> bool:
        b = self._buckets[charge.entitlement]
        if not b.charge(charge.charged_tokens, now):
            return False
        self._charges[charge.request_id] = charge
        return True

    def charge_batch(self, charges: list[Charge], now: float
                     ) -> list[bool]:
        """Apply one admission quantum's charges in order: each bucket
        refills ONCE (all charges share ``now``, so per-charge refills
        are no-ops after the first) and every charge still re-checks
        affordability — the ledger stays authoritative even if the
        caller pre-validated on a snapshot."""
        refilled: set[str] = set()
        out = []
        for ch in charges:
            b = self._buckets[ch.entitlement]
            if ch.entitlement not in refilled:
                b.refill(now)
                refilled.add(ch.entitlement)
            if b.level >= ch.charged_tokens:
                b.level -= ch.charged_tokens
                self._charges[ch.request_id] = ch
                out.append(True)
            else:
                out.append(False)
        return out

    def settle(self, request_id: str, actual_output_tokens: int,
               now: float) -> float:
        """Completion callback: refund the unused reservation.

        Returns the *actual* token cost (input + actual output)."""
        ch = self._charges.pop(request_id, None)
        if ch is None:
            return 0.0
        actual = ch.input_tokens + actual_output_tokens
        refund = max(0.0, ch.charged_tokens - actual)
        self._buckets[ch.entitlement].refund(refund, now)
        return float(actual)

    def cancel(self, request_id: str, now: float) -> None:
        """Request failed/evicted before producing tokens: full refund."""
        ch = self._charges.pop(request_id, None)
        if ch is not None:
            self._buckets[ch.entitlement].refund(ch.charged_tokens, now)

    def retry_after(self, entitlement: str, tokens: float, now: float) -> float:
        return self._buckets[entitlement].time_until_affordable(tokens, now)
