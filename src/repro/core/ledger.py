"""Token-throughput ledger: per-entitlement token budgets.

The paper's admission check (4) requires that "the request's token
budget (input tokens plus max_tokens) must fit within the entitlement's
remaining throughput allocation" (§4.3).  We realise the throughput
entitlement λ_e (tokens/second) as a token bucket:

  - the bucket refills continuously at the entitlement's *effective*
    rate λ̂_e (which the pool controller adjusts: shrunk under
    contention, grown by work-conserving backfill);
  - bucket capacity is ``burst_window_s`` seconds of the rate, so short
    bursts above λ are fundable from accumulated idle credit, matching
    the paper's "burst capacity is satisfied by reallocating unused
    tokens before triggering scaling";
  - admission *charges* the nominal cost n_in + n_out_max up front and
    the completion callback *refunds* the unused portion
    (max_tokens − actual output), closing the admission/execution gap.

Storage has two modes sharing one semantics:

  - **resident** (``Ledger(store=...)`` — what ``TokenPool`` uses):
    bucket level / rate / refill-clock live as float64 COLUMNS of the
    pool's :class:`~repro.core.resident.ResidentStore`;
    :class:`RowBucket` is a view over one row with the exact
    ``TokenBucket`` API, and ``set_rate_rows`` updates every bucket of
    an accounting tick as one vectorized row operation (the per-name
    ``set_rate`` loop the tick used to run was O(n) Python);
  - **standalone** (no store): plain ``TokenBucket`` objects in a dict,
    for tests and detached/migrating buckets.

Deterministic; time is an explicit argument.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np

from repro.core.markers import hot_path


@dataclasses.dataclass
class TokenBucket:
    rate_tps: float                 # current refill rate λ̂_e
    burst_window_s: float = 4.0     # bucket capacity = rate · window
    level: float = 0.0              # current tokens available
    last_refill_s: float = 0.0

    def capacity(self) -> float:
        return self.rate_tps * self.burst_window_s

    def refill(self, now: float) -> None:
        dt = max(0.0, now - self.last_refill_s)
        self.level = min(self.capacity(), self.level + dt * self.rate_tps)
        self.last_refill_s = now

    def set_rate(self, rate_tps: float, now: float) -> None:
        """Adjust the refill rate (pool shrink/backfill).  Refill first so
        credit accrued at the old rate is preserved, then clamp to the
        new capacity."""
        self.refill(now)
        self.rate_tps = max(0.0, rate_tps)
        self.level = min(self.level, self.capacity())

    def can_afford(self, tokens: float, now: float) -> bool:
        self.refill(now)
        return self.level >= tokens

    def charge(self, tokens: float, now: float) -> bool:
        self.refill(now)
        if self.level < tokens:
            return False
        self.level -= tokens
        return True

    def refund(self, tokens: float, now: float) -> None:
        self.refill(now)
        self.level = min(self.capacity(), self.level + max(0.0, tokens))

    def time_until_affordable(self, tokens: float, now: float) -> float:
        """Seconds until ``tokens`` would be available — the Retry-After
        hint returned with HTTP 429 (paper §4.3)."""
        self.refill(now)
        deficit = tokens - self.level
        if deficit <= 0:
            return 0.0
        if self.rate_tps <= 0:
            return float("inf")
        return deficit / self.rate_tps


class RowBucket:
    """``TokenBucket``-API view over one resident-store row.

    Level / rate / refill clock live in the store's float64 bucket
    columns (the arrays are the truth); this object carries no state of
    its own, so two views of the same row can never diverge.
    """

    __slots__ = ("_store", "_slot")

    def __init__(self, store, slot: int) -> None:
        self._store = store
        self._slot = slot

    # -- column-backed fields (same names as the dataclass) -------------------
    @property
    def rate_tps(self) -> float:
        return float(self._store.col["bucket_rate"][self._slot])

    @rate_tps.setter
    def rate_tps(self, v: float) -> None:
        self._store.col["bucket_rate"][self._slot] = v

    @property
    def level(self) -> float:
        return float(self._store.col["bucket_level"][self._slot])

    @level.setter
    def level(self, v: float) -> None:
        self._store.col["bucket_level"][self._slot] = v
        audit = self._store.level_audit
        if audit is not None:
            audit.note("scalar", self._slot)

    @property
    def burst_window_s(self) -> float:
        return float(self._store.col["bucket_window"][self._slot])

    @burst_window_s.setter
    def burst_window_s(self, v: float) -> None:
        self._store.col["bucket_window"][self._slot] = v

    @property
    def last_refill_s(self) -> float:
        return float(self._store.col["bucket_refill"][self._slot])

    @last_refill_s.setter
    def last_refill_s(self, v: float) -> None:
        self._store.col["bucket_refill"][self._slot] = v

    # -- TokenBucket semantics, verbatim --------------------------------------
    capacity = TokenBucket.capacity
    refill = TokenBucket.refill
    set_rate = TokenBucket.set_rate
    can_afford = TokenBucket.can_afford
    charge = TokenBucket.charge
    refund = TokenBucket.refund
    time_until_affordable = TokenBucket.time_until_affordable

    def to_token_bucket(self) -> TokenBucket:
        """Materialize a detached plain bucket (migration payloads)."""
        return TokenBucket(rate_tps=self.rate_tps,
                           burst_window_s=self.burst_window_s,
                           level=self.level,
                           last_refill_s=self.last_refill_s)

    def __repr__(self) -> str:
        return (f"RowBucket(slot={self._slot}, rate_tps={self.rate_tps}, "
                f"level={self.level}, window={self.burst_window_s})")


Bucket = Union[TokenBucket, RowBucket]


class LevelAudit:
    """Opt-in conservation ledger for the ``bucket_level`` column.

    Every SANCTIONED mutation site (scalar ``RowBucket.level`` writes,
    the vectorized charge/refund/rate row-ops, bucket init/teardown,
    store row recycling) notifies the audit after mutating, which
    accrues the net delta into a per-kind flow total and advances the
    per-slot ``expected`` mirror.  The conservation invariant is then

        bucket_level[s] == expected[s]            (per slot)
        Σ level − Σ baseline == Σ flows           (in aggregate)

    i.e. refills − charges + refunds (+ init/teardown) fully explain
    the observed level deltas.  Any write that bypasses the sanctioned
    entry points (a stray ``col["bucket_level"]`` poke) shows up as
    non-zero :meth:`drift`.  Off by default — production paths pay one
    attribute load + ``is None`` check per mutation batch."""

    def __init__(self, store) -> None:
        self._store = store
        self.expected = store.col["bucket_level"].astype(np.float64)
        #: net level delta per sanctioned-flow kind ("refill",
        #: "charge", "refund", "init", "lifecycle", "scalar")
        self.flows: dict[str, float] = {}
        self.baseline_total = float(self.expected.sum())

    def _sync_width(self) -> None:
        cap = self._store.capacity
        if len(self.expected) < cap:        # store grew: pad with zeros
            grown = np.zeros(cap, np.float64)
            grown[:len(self.expected)] = self.expected
            self.expected = grown

    def note(self, kind: str, slots=None) -> None:
        """Absorb the level delta at ``slots`` (an int, an index array,
        or None for full width) as sanctioned flow of ``kind``."""
        self._sync_width()
        lvl = self._store.col["bucket_level"]
        if slots is None:
            delta = float(lvl.sum() - self.expected.sum())
            self.expected = lvl.astype(np.float64)
        elif np.ndim(slots) == 0:
            delta = float(lvl[slots] - self.expected[slots])
            self.expected[slots] = lvl[slots]
        else:
            u = np.unique(np.asarray(slots, np.int64))
            delta = float(lvl[u].sum() - self.expected[u].sum())
            self.expected[u] = lvl[u]
        self.flows[kind] = self.flows.get(kind, 0.0) + delta

    def drift(self) -> np.ndarray:
        """Per-slot unsanctioned level movement (actual − expected);
        all-zero when every mutation went through a sanctioned path."""
        self._sync_width()
        return (self._store.col["bucket_level"]
                - self.expected[:self._store.capacity])

    def conservation_gap(self) -> float:
        """|Σ level − (Σ baseline + Σ flows)| — 0.0 when the flow
        ledger fully explains the column."""
        total = float(self._store.col["bucket_level"].sum())
        return abs(total - (self.baseline_total
                            + sum(self.flows.values())))


@dataclasses.dataclass
class Charge:
    """Record of an admission-time charge, so completion can refund."""

    request_id: str
    entitlement: str
    charged_tokens: float
    input_tokens: int
    max_tokens: int
    admitted_at: float


class Ledger:
    """Per-entitlement token buckets + outstanding charges.

    Charges follow the same two-mode storage as buckets: with a
    request ``table`` (``core.request_table.RequestTable`` — what
    ``TokenPool`` wires up) each outstanding charge is the charge half
    of a request-table ROW, and the batched entry points
    (:meth:`charge_rows`, :meth:`settle_rows`, :meth:`cancel_rows`)
    are vectorized column ops; without one, charges are plain
    ``Charge`` dataclasses in a dict (tests, detached/migrating
    state)."""

    def __init__(self, burst_window_s: float = 4.0, store=None,
                 table=None) -> None:
        #: standalone mode only; resident mode derives buckets from the
        #: store columns (``has_bucket`` + the bucket_* columns)
        self._buckets: dict[str, TokenBucket] = {}
        #: standalone mode only; table mode keeps charges on rows
        self._charges: dict[str, Charge] = {}
        self.burst_window_s = burst_window_s
        self._store = store
        self._table = table
        #: settles/cancels for request ids with no outstanding charge —
        #: silently 0.0/no-op by contract (late duplicate completions),
        #: but counted so lifecycle bugs can't hide (surfaced through
        #: ``TokenPool.stats``)
        self.unknown_settles = 0

    # -- conservation audit (opt-in) -------------------------------------------
    @property
    def level_audit(self) -> Optional[LevelAudit]:
        """The active :class:`LevelAudit` (None unless enabled)."""
        return None if self._store is None else self._store.level_audit

    def enable_level_audit(self) -> LevelAudit:
        """Start auditing sanctioned ``bucket_level`` flows (resident
        mode only) — the chaos harness's token-conservation checker
        reads :meth:`LevelAudit.drift` after every quantum."""
        if self._store is None:
            raise ValueError("level audit requires resident mode")
        if self._store.level_audit is None:
            self._store.level_audit = LevelAudit(self._store)
        return self._store.level_audit

    def _audit_note(self, kind: str, slots) -> None:
        if self._store is not None \
                and self._store.level_audit is not None:
            self._store.level_audit.note(kind, slots)

    # -- charge storage (both modes) -------------------------------------------
    def _put_charge(self, charge: Charge) -> None:
        if self._table is None:
            self._charges[charge.request_id] = charge
        else:
            self._table.put_charge(charge)

    def _pop_charge(self, request_id: str) -> Optional[Charge]:
        if self._table is None:
            return self._charges.pop(request_id, None)
        return self._table.pop_charge(request_id)

    def outstanding_charges(self) -> int:
        if self._table is None:
            return len(self._charges)
        return int(np.count_nonzero(self._table.col["has_charge"]))

    # -- bucket resolution (both modes) ----------------------------------------
    def _slot(self, entitlement: str) -> int:
        """Resident slot of an entitlement's bucket row; KeyError when
        the entitlement is unknown OR holds no bucket (dict-miss parity
        with the standalone mode)."""
        slot = self._store.slot_of[entitlement]
        if not self._store.col["has_bucket"][slot]:
            raise KeyError(entitlement)
        return slot

    def bucket(self, entitlement: str) -> Bucket:
        if self._store is None:
            return self._buckets[entitlement]
        return RowBucket(self._store, self._slot(entitlement))

    def has_bucket(self, entitlement: str) -> bool:
        if self._store is None:
            return entitlement in self._buckets
        slot = self._store.slot_of.get(entitlement)
        return slot is not None and bool(
            self._store.col["has_bucket"][slot])

    def ensure(self, entitlement: str, rate_tps: float,
               now: float) -> Bucket:
        if self._store is None:
            b = self._buckets.get(entitlement)
            if b is None:
                b = TokenBucket(rate_tps=rate_tps,
                                burst_window_s=self.burst_window_s,
                                level=rate_tps * self.burst_window_s,
                                last_refill_s=now)
                self._buckets[entitlement] = b
            return b
        slot = self._store.slot_of[entitlement]
        c = self._store.col
        if not c["has_bucket"][slot]:
            c["has_bucket"][slot] = True
            c["bucket_rate"][slot] = rate_tps
            c["bucket_window"][slot] = self.burst_window_s
            c["bucket_level"][slot] = rate_tps * self.burst_window_s
            c["bucket_refill"][slot] = now
            self._audit_note("init", slot)
        return RowBucket(self._store, slot)

    @hot_path
    def ensure_rows(self, slots: np.ndarray, rates: np.ndarray,
                    now: float) -> None:
        """Vectorized get-or-create over resident bucket rows (resident
        mode only).  Rows that already hold a bucket are untouched;
        the rest are initialized with the per-row ``rates`` exactly as
        :meth:`ensure` would — one masked column write per field
        instead of a per-entitlement Python loop."""
        c = self._store.col
        need = ~c["has_bucket"][slots]
        if not need.any():
            return
        ns = slots[need]
        r = np.asarray(rates, np.float64)[need]
        c["has_bucket"][ns] = True
        c["bucket_rate"][ns] = r
        c["bucket_window"][ns] = self.burst_window_s
        c["bucket_level"][ns] = r * self.burst_window_s
        c["bucket_refill"][ns] = now
        self._audit_note("init", ns)

    def peek_level(self, entitlement: str, rate_tps: float,
                   now: float) -> float:
        """Level the bucket WOULD have after a refill at ``now`` — pure
        read: no bucket is created and no refill clock advances.  For an
        entitlement with no bucket yet, this is the full initial level
        ``ensure`` would create.  Snapshotting code (the batched
        admission quantum) uses this so observing a pool never mutates
        it."""
        try:
            b = self.bucket(entitlement)
        except KeyError:
            return rate_tps * self.burst_window_s
        dt = max(0.0, now - b.last_refill_s)
        return min(b.capacity(), b.level + dt * b.rate_tps)

    @hot_path
    def peek_levels(self, rates: np.ndarray, now: float) -> np.ndarray:
        """Vectorized :meth:`peek_level` over EVERY resident row (pure
        read; resident mode only).  ``rates`` supplies the would-be
        initial rate for rows without a bucket (the effective-or-
        baseline fallback the scalar path uses).  Rows are in slot
        order — one O(width) numpy expression replaces the per-name
        loop the admission snapshot used to run."""
        c = self._store.col
        cap = c["bucket_rate"] * c["bucket_window"]
        dt = np.maximum(0.0, now - c["bucket_refill"])
        projected = np.minimum(cap, c["bucket_level"]
                               + dt * c["bucket_rate"])
        return np.where(c["has_bucket"], projected,
                        np.asarray(rates, np.float64)
                        * self.burst_window_s)

    def drop(self, entitlement: str) -> None:
        """Remove an entitlement's bucket and any outstanding charges
        (entitlement teardown — the bucket must stop refilling)."""
        if self._store is None:
            self._buckets.pop(entitlement, None)
        else:
            self.drop_bucket_only(entitlement)
        if self._table is not None:
            slot = self._store.slot_of.get(entitlement)
            if slot is not None:
                for s in self._table.charge_slots_of_owner(slot):
                    self._table.clear_charge(s)
            return
        for rid in [rid for rid, ch in self._charges.items()
                    if ch.entitlement == entitlement]:
            del self._charges[rid]

    # -- migration (cross-pool entitlement rebalancing) ------------------------
    def detach(self, entitlement: str
               ) -> tuple[Optional[TokenBucket], list[Charge]]:
        """Remove and RETURN an entitlement's bucket + outstanding
        charges so they can be re-attached on another pool's ledger.
        Unlike :meth:`drop`, nothing is forgotten: the accrued bucket
        level and every admission-time charge (still owed a refund on
        completion) travel with the entitlement.  Resident-mode buckets
        are materialized into detached ``TokenBucket`` objects (the row
        is about to be recycled)."""
        bucket: Optional[TokenBucket]
        if self._store is None:
            bucket = self._buckets.pop(entitlement, None)
        else:
            try:
                bucket = RowBucket(
                    self._store, self._slot(entitlement)).to_token_bucket()
            except KeyError:
                bucket = None
            self.drop_bucket_only(entitlement)
        if self._table is not None:
            slot = self._store.slot_of.get(entitlement)
            charges = []
            if slot is not None:
                for s in self._table.charge_slots_of_owner(slot):
                    charges.append(self._table.materialize_charge(s))
                    self._table.clear_charge(s)
            return bucket, charges
        charges = [ch for ch in self._charges.values()
                   if ch.entitlement == entitlement]
        for ch in charges:
            del self._charges[ch.request_id]
        return bucket, charges

    def drop_bucket_only(self, entitlement: str) -> None:
        """Clear a resident bucket row without touching charges."""
        slot = self._store.slot_of.get(entitlement)
        if slot is not None:
            c = self._store.col
            c["has_bucket"][slot] = False
            c["bucket_level"][slot] = 0.0
            c["bucket_rate"][slot] = 0.0
            c["bucket_refill"][slot] = 0.0
            c["bucket_window"][slot] = 0.0
            self._audit_note("lifecycle", slot)

    def attach(self, entitlement: str, bucket: Optional[TokenBucket],
               charges: list[Charge], now: float) -> None:
        """Adopt a migrated bucket + charges.  The bucket keeps its
        accrued level and refill rate; only the burst window is
        re-based to THIS ledger's window (clamping the level if the
        new capacity is smaller) — the target pool's TPM semantics
        apply from the moment of the move."""
        if bucket is not None:
            bucket.refill(now)
            bucket.burst_window_s = self.burst_window_s
            bucket.level = min(bucket.level, bucket.capacity())
            if self._store is None:
                self._buckets[entitlement] = bucket
            else:
                slot = self._store.slot_of[entitlement]
                c = self._store.col
                c["has_bucket"][slot] = True
                c["bucket_rate"][slot] = bucket.rate_tps
                c["bucket_window"][slot] = bucket.burst_window_s
                c["bucket_level"][slot] = bucket.level
                c["bucket_refill"][slot] = bucket.last_refill_s
                self._audit_note("init", slot)
        for ch in charges:
            self._put_charge(ch)

    def set_rate(self, entitlement: str, rate_tps: float, now: float) -> None:
        self.ensure(entitlement, rate_tps, now).set_rate(rate_tps, now)

    @hot_path
    def set_rate_rows(self, mask: np.ndarray, rates: np.ndarray,
                      now: float) -> None:
        """One accounting tick's rate updates as a single vectorized row
        operation (resident mode): for every row where ``mask`` is
        True, apply exactly ``TokenBucket.set_rate`` — refill at the
        old rate, adopt the (non-negative) new rate, clamp to the new
        capacity.  Masked rows without a bucket yet get a fresh one at
        the new rate, matching what ``ensure`` + ``set_rate`` would
        create.  ``mask``/``rates`` are full-width (slot-indexed)."""
        c = self._store.col
        has = c["has_bucket"] & mask
        rate = c["bucket_rate"]
        window = c["bucket_window"]
        dt = np.maximum(0.0, now - c["bucket_refill"])
        refilled = np.minimum(rate * window,
                              c["bucket_level"] + dt * rate)
        new_rate = np.maximum(0.0, np.asarray(rates, np.float64))
        clamped = np.minimum(refilled, new_rate * window)
        fresh = mask & ~c["has_bucket"]
        c["bucket_level"][:] = np.where(
            has, clamped,
            np.where(fresh, new_rate * self.burst_window_s,
                     c["bucket_level"]))
        c["bucket_rate"][:] = np.where(mask, new_rate, rate)
        c["bucket_window"][:] = np.where(
            fresh, self.burst_window_s, window)
        c["bucket_refill"][:] = np.where(mask, now, c["bucket_refill"])
        c["has_bucket"][:] = c["has_bucket"] | mask
        self._audit_note("refill", None)

    def charge(self, charge: Charge, now: float) -> bool:
        b = self.bucket(charge.entitlement)
        if not b.charge(charge.charged_tokens, now):
            return False
        self._put_charge(charge)
        return True

    @hot_path
    def charge_batch(self, charges: list[Charge], now: float
                     ) -> list[bool]:
        """Apply one admission quantum's charges in order: each bucket
        refills ONCE (all charges share ``now``, so per-charge refills
        are no-ops after the first) and every charge still re-checks
        affordability — the ledger stays authoritative even if the
        caller pre-validated on a snapshot.

        Table mode runs the vectorized row-op (:meth:`charge_rows`
        machinery): one refill per touched bucket + a per-entitlement
        ordered prefix-sum affordability check, falling back to the
        scalar greedy replay for any entitlement whose quantum does not
        fit entirely (a mid-group failure skips that charge and keeps
        admitting later ones — cumulative sums can't express that).
        An unknown entitlement falls back wholesale so the scalar
        KeyError surfaces at the same charge index."""
        if self._table is None or not charges:
            return self._charge_batch_scalar(charges, now)
        n = len(charges)
        sc = self._store.col
        slot_by_ent: dict[str, int] = {}
        ent_slot = np.empty(n, np.int64)
        for i, ch in enumerate(charges):
            s = slot_by_ent.get(ch.entitlement)
            if s is None:
                s = self._store.slot_of.get(ch.entitlement)
                if s is None or not sc["has_bucket"][s]:
                    return self._charge_batch_scalar(charges, now)
                slot_by_ent[ch.entitlement] = s
            ent_slot[i] = s
        tokens = np.fromiter((ch.charged_tokens for ch in charges),
                             np.float64, count=n)
        ok = self._charge_decide_rows(ent_slot, tokens, now)
        acc = np.flatnonzero(ok)
        if acc.size:
            self._table.put_charges([charges[i] for i in acc],
                                    ent_slot[acc])
        return ok.tolist()

    def _charge_batch_scalar(self, charges: list[Charge], now: float
                             ) -> list[bool]:
        """The retained per-charge loop (standalone mode + the table
        mode fallback) — the parity oracle for the vectorized path."""
        refilled: set[str] = set()
        out = []
        for ch in charges:
            b = self.bucket(ch.entitlement)
            if ch.entitlement not in refilled:
                b.refill(now)
                refilled.add(ch.entitlement)
            if b.level >= ch.charged_tokens:
                b.level -= ch.charged_tokens
                self._put_charge(ch)
                out.append(True)
            else:
                out.append(False)
        return out

    @hot_path
    def _charge_decide_rows(self, ent_slot: np.ndarray,
                            tokens: np.ndarray, now: float) -> np.ndarray:
        """Vectorized affordability for one quantum of charges against
        resident buckets (``ent_slot``/``tokens`` aligned, every slot
        pre-validated to hold a bucket).  Mutates bucket levels exactly
        like the scalar loop and returns the accept mask.

        Parity with the scalar greedy: each touched bucket refills once
        at the shared ``now`` (later per-charge refills are dt=0
        no-ops); a stable argsort groups charges by bucket while
        preserving arrival order inside each group, so when a group's
        inclusive prefix sums all fit the opening level, committing via
        ``np.subtract.at`` (unbuffered, index-ordered) replays the
        identical f64 subtraction chain.  Any group with a miss is
        replayed charge by charge in arrival order instead."""
        sc = self._store.col
        lvl = sc["bucket_level"]
        u = np.unique(ent_slot)
        cap = sc["bucket_rate"][u] * sc["bucket_window"][u]
        dt = np.maximum(0.0, now - sc["bucket_refill"][u])
        lvl[u] = np.minimum(cap, lvl[u] + dt * sc["bucket_rate"][u])
        sc["bucket_refill"][u] = now
        self._audit_note("refill", u)
        n = len(ent_slot)
        order = np.argsort(ent_slot, kind="stable")
        s_ord = ent_slot[order]
        t_ord = tokens[order]
        cum = np.cumsum(t_ord)
        group_start = np.empty(n, bool)
        group_start[0] = True
        group_start[1:] = s_ord[1:] != s_ord[:-1]
        start_idx = np.flatnonzero(group_start)
        gid = np.cumsum(group_start) - 1
        base = np.concatenate(([0.0], cum[start_idx[1:] - 1]))
        prefix = cum - base[gid]
        fits = prefix <= lvl[s_ord]
        group_ok = np.logical_and.reduceat(fits, start_idx)
        fast = group_ok[gid]
        ok = np.zeros(n, bool)
        if fast.any():
            np.subtract.at(lvl, s_ord[fast], t_ord[fast])
            ok[order[fast]] = True
        if not fast.all():
            for pos in np.flatnonzero(~fast):
                s = s_ord[pos]
                t = t_ord[pos]
                if lvl[s] >= t:
                    lvl[s] -= t
                    ok[order[pos]] = True
        self._audit_note("charge", u)
        return ok

    @hot_path
    def charge_rows(self, request_ids: list, ent_slot: np.ndarray,
                    tokens: np.ndarray, input_tokens: np.ndarray,
                    max_tokens: np.ndarray, now: float
                    ) -> tuple[np.ndarray, np.ndarray]:
        """Array-native :meth:`charge_batch` — the gateway quantum hot
        path: no per-request ``Charge`` objects, accepted charges land
        as batched request-table column writes.  Every ``ent_slot``
        must hold a bucket (the gateway ensures buckets per entitlement
        beforehand).  Returns ``(accept mask, accepted row slots)`` —
        the slots align with the accepted subset in charge order, so
        the caller can thread them straight into the admit scatter."""
        ok = self._charge_decide_rows(
            np.asarray(ent_slot, np.int64),
            np.asarray(tokens, np.float64), now)
        acc = np.flatnonzero(ok)
        slots = np.empty(0, np.int64)
        if acc.size:
            acc_l = acc.tolist()
            ids = (request_ids if acc.size == len(request_ids)
                   else [request_ids[i] for i in acc_l])
            slots = self._table.charge_rows(
                ids, ent_slot[acc],
                np.asarray(tokens, np.float64)[acc],
                np.asarray(input_tokens, np.int64)[acc],
                np.asarray(max_tokens, np.int64)[acc], now)
        return ok, slots

    def settle(self, request_id: str, actual_output_tokens: int,
               now: float) -> float:
        """Completion callback: refund the unused reservation.

        Returns the *actual* token cost (input + actual output);
        0.0 — counted in ``unknown_settles`` — when no charge is
        outstanding for the request."""
        ch = self._pop_charge(request_id)
        if ch is None:
            self.unknown_settles += 1
            return 0.0
        actual = ch.input_tokens + actual_output_tokens
        refund = max(0.0, ch.charged_tokens - actual)
        self.bucket(ch.entitlement).refund(refund, now)
        return float(actual)

    def cancel(self, request_id: str, now: float) -> None:
        """Request failed/evicted before producing tokens: full refund.
        Unknown request ids no-op but count in ``unknown_settles``."""
        ch = self._pop_charge(request_id)
        if ch is None:
            self.unknown_settles += 1
            return
        self.bucket(ch.entitlement).refund(ch.charged_tokens, now)

    @hot_path
    def _refund_rows(self, ch_owner: np.ndarray, refunds: np.ndarray,
                     now: float) -> None:
        """Batched ``TokenBucket.refund`` over bucket rows: one refill
        per touched bucket at the shared ``now``, refunds applied with
        ``np.add.at`` (unbuffered, index-ordered — the same f64
        addition chain as sequential scalar refunds), one capacity
        clamp at the end.  Clamp-once equals clamp-each: refunds are
        non-negative, so once the running level would exceed capacity
        every subsequent scalar step re-clamps to the same cap."""
        sc = self._store.col
        lvl = sc["bucket_level"]
        u = np.unique(ch_owner)
        cap = sc["bucket_rate"][u] * sc["bucket_window"][u]
        dt = np.maximum(0.0, now - sc["bucket_refill"][u])
        lvl[u] = np.minimum(cap, lvl[u] + dt * sc["bucket_rate"][u])
        sc["bucket_refill"][u] = now
        self._audit_note("refill", u)
        np.add.at(lvl, ch_owner, refunds)
        lvl[u] = np.minimum(lvl[u], cap)
        self._audit_note("refund", u)

    @hot_path
    def settle_rows(self, slots: np.ndarray, actual_output_tokens:
                    np.ndarray, now: float) -> np.ndarray:
        """Batched :meth:`settle` over request-table rows (table mode).
        Folds every refund into one vectorized bucket update and clears
        the charge halves; the caller owns releasing the rows.  Rows
        with no outstanding charge settle to 0.0 and count in
        ``unknown_settles``.  Returns per-row actual token costs."""
        t = self._table
        c = t.col
        n = len(slots)
        actual = np.zeros(n, np.float64)
        has = c["has_charge"][slots]
        missing = n - int(np.count_nonzero(has))
        if missing:
            self.unknown_settles += missing
        if missing == n:
            return actual
        cs = slots[has]
        owners = c["ch_owner"][cs].astype(np.int64)
        bad = ~self._store.col["has_bucket"][owners]
        if bad.any():          # KeyError parity with the scalar settle
            raise KeyError(self._store.name_of[int(owners[bad][0])])
        outs = np.asarray(actual_output_tokens, np.int64)[has]
        act = (c["input_tokens"][cs] + outs).astype(np.float64)
        refunds = np.maximum(0.0, c["charged"][cs] - act)
        self._refund_rows(owners, refunds, now)
        actual[has] = act
        c["has_charge"][cs] = False
        c["ch_owner"][cs] = 0
        c["charged"][cs] = 0.0
        c["input_tokens"][cs] = 0
        c["max_tokens"][cs] = 0
        c["ch_admitted"][cs] = 0.0
        return actual

    @hot_path
    def cancel_rows(self, slots: np.ndarray, now: float) -> None:
        """Batched :meth:`cancel` over request-table rows (table
        mode): full refunds, vectorized.  The caller owns releasing
        the rows."""
        t = self._table
        c = t.col
        has = c["has_charge"][slots]
        missing = len(slots) - int(np.count_nonzero(has))
        if missing:
            self.unknown_settles += missing
        if missing == len(slots):
            return
        cs = slots[has]
        owners = c["ch_owner"][cs].astype(np.int64)
        bad = ~self._store.col["has_bucket"][owners]
        if bad.any():
            raise KeyError(self._store.name_of[int(owners[bad][0])])
        refunds = np.maximum(0.0, c["charged"][cs])
        self._refund_rows(owners, refunds, now)
        c["has_charge"][cs] = False
        c["ch_owner"][cs] = 0
        c["charged"][cs] = 0.0
        c["input_tokens"][cs] = 0
        c["max_tokens"][cs] = 0
        c["ch_admitted"][cs] = 0.0

    def retry_after(self, entitlement: str, tokens: float, now: float) -> float:
        return self.bucket(entitlement).time_until_affordable(tokens, now)
