"""Priority, debt, and burst math — paper §3.3, Eqs. (1)–(3).

Scalar reference implementation.  ``core.vectorized`` provides a
jit-compiled jnp batch equivalent; ``tests/test_vectorized_equiv.py``
pins the two equal with hypothesis.

All functions are pure: state in, state out.
"""
from __future__ import annotations

import dataclasses

from repro.core.types import (
    CLASS_WEIGHT,
    PriorityCoefficients,
    Resources,
    ServiceClass,
)


def priority_weight(
    service_class: ServiceClass,
    slo_target_ms: float,
    pool_avg_slo_ms: float,
    burst: float,
    debt: float,
    coeff: PriorityCoefficients = PriorityCoefficients(),
) -> float:
    """Eq. (1):

        w_e = w_κ · (1 + α_slo · ℓ*_e / ℓ̄*)⁻¹
                  · (1 + α_burst · b_e)⁻¹
                  · (1 + α_debt · d_e)

    Tighter SLO targets (small ℓ*_e) yield higher priority; sustained
    bursting reduces priority; positive accumulated debt raises it.

    The debt factor may be < 1 when d_e < 0 (credit from overservice),
    but is floored at a small positive value so priority never goes
    non-positive for a live entitlement.
    """
    w_class = CLASS_WEIGHT[service_class]
    slo_factor = 1.0 / (1.0 + coeff.alpha_slo * (slo_target_ms / pool_avg_slo_ms))
    burst_factor = 1.0 / (1.0 + coeff.alpha_burst * max(0.0, burst))
    debt_factor = max(1e-3, 1.0 + coeff.alpha_debt * debt)
    return w_class * slo_factor * burst_factor * debt_factor


def service_gap(baseline_tps: float, allocated_tps: float) -> float:
    """g_e = (λ_e − λ̂_e) / λ_e  (paper §3.3).

    Positive ⇒ underserved (allocation below baseline); negative ⇒
    overserved (bursting above baseline).  Zero-baseline entitlements
    (spot/preemptible) have no defined gap; return 0.
    """
    if baseline_tps <= 0.0:
        return 0.0
    return (baseline_tps - allocated_tps) / baseline_tps


def debt_update(debt_prev: float, gap: float, gamma_d: float) -> float:
    """Eq. (2):  d_e(k) = γ_d · d_e(k−1) + (1 − γ_d) · g_e(k).

    EWMA accumulation — the integral term of the PI analogy, with the
    decay acting as anti-windup.
    """
    return gamma_d * debt_prev + (1.0 - gamma_d) * gap


def burst_overconsumption(usage: Resources, baseline: Resources) -> float:
    """Eq. (3): instantaneous multi-dimensional overconsumption

        δ_e = max(0, λ̂/λ − 1) + max(0, χ̂/χ − 1) + max(0, r̂/r − 1)

    Dimensions with zero baseline contribute their full relative usage
    (a zero-baseline entitlement consuming anything is pure burst); the
    paper's spot class has no baseline, so any consumption is burst.
    We normalise zero-baseline dimensions against a unit scale to keep
    δ finite, matching "consume only surplus capacity" semantics.
    """

    def term(used: float, base: float) -> float:
        if base <= 0.0:
            # No baseline: any use is overconsumption.  Normalise by the
            # usage itself → contributes 1.0 when active, 0 when idle.
            return 1.0 if used > 0.0 else 0.0
        return max(0.0, used / base - 1.0)

    return (
        term(usage.tokens_per_second, baseline.tokens_per_second)
        + term(usage.kv_bytes, baseline.kv_bytes)
        + term(usage.concurrency, baseline.concurrency)
    )


def burst_update(burst_prev: float, delta: float, gamma_b: float) -> float:
    """EWMA of Eq. (3): b_e(k) = γ_b · b_e(k−1) + (1 − γ_b) · δ_e(k)."""
    return gamma_b * burst_prev + (1.0 - gamma_b) * delta


def pool_average_slo(slo_targets_ms: list[float]) -> float:
    """ℓ̄* — arithmetic mean of member SLO targets (paper §5.3 uses the
    mean of the participating entitlements: (500+30000+...)/n)."""
    if not slo_targets_ms:
        return 1.0
    return sum(slo_targets_ms) / len(slo_targets_ms)


@dataclasses.dataclass(frozen=True)
class PriorityBreakdown:
    """All factors of Eq. 1, for observability panels (paper Fig. 5)."""

    w_class: float
    slo_factor: float
    burst_factor: float
    debt_factor: float
    weight: float


def priority_breakdown(
    service_class: ServiceClass,
    slo_target_ms: float,
    pool_avg_slo_ms: float,
    burst: float,
    debt: float,
    coeff: PriorityCoefficients = PriorityCoefficients(),
) -> PriorityBreakdown:
    w_class = CLASS_WEIGHT[service_class]
    slo_factor = 1.0 / (1.0 + coeff.alpha_slo * (slo_target_ms / pool_avg_slo_ms))
    burst_factor = 1.0 / (1.0 + coeff.alpha_burst * max(0.0, burst))
    debt_factor = max(1e-3, 1.0 + coeff.alpha_debt * debt)
    return PriorityBreakdown(
        w_class=w_class,
        slo_factor=slo_factor,
        burst_factor=burst_factor,
        debt_factor=debt_factor,
        weight=w_class * slo_factor * burst_factor * debt_factor,
    )
