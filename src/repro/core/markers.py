"""Structural markers consumed by the static analyzer (``repro.analysis``).

Two decorators, both ZERO overhead at call time — they record the
function in a module-level registry and return it unchanged, so
decorating a jit kernel or a hot path costs nothing per call (the
BENCH gates see the same function object):

* :func:`kernel` — registers a jit-compiled kernel together with the
  dotted path of its retained scalar oracle.  The oracle-parity pass
  cross-references ``tests/`` to prove every registered kernel has a
  parity test importing both the kernel and its oracle, so a new
  kernel without an oracle pin fails CI.
* :func:`hot_path` — marks a function as a vectorized hot path: the
  hot-path-scalar-loop pass forbids per-row Python ``for`` loops /
  comprehensions over store or table row containers inside it (waive
  with ``# repro: allow[hot-path-scalar-loop] -- <reason>``).

The analyzer reads the DECORATIONS from the AST (it never imports the
annotated modules), but the runtime registries below let tests assert
the adoption surface and keep the decorator honest about overhead.
"""
from __future__ import annotations

import dataclasses

__all__ = ["HOT_PATHS", "KERNELS", "KernelSpec", "hot_path", "kernel"]


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered jit kernel and the scalar oracle that pins it."""

    name: str
    module: str
    oracle: str          # dotted path, e.g. "repro.core.control_plane.reference_tick"


#: kernel name → spec, filled at import time by :func:`kernel`.
KERNELS: dict[str, KernelSpec] = {}

#: "module.qualname" of every function marked :func:`hot_path`.
HOT_PATHS: dict[str, str] = {}


def kernel(*, oracle: str):
    """Register a jit kernel with the dotted path of its scalar parity
    oracle.  Apply OUTSIDE ``jax.jit`` so the registered (and returned)
    object is the compiled entry point itself::

        @kernel(oracle="repro.core.control_plane.reference_tick")
        @partial(jax.jit, static_argnames=("coeff",))
        def control_tick(...): ...
    """

    def register(fn):
        KERNELS[fn.__name__] = KernelSpec(
            name=fn.__name__, module=fn.__module__, oracle=oracle)
        return fn

    return register


def hot_path(fn):
    """Mark ``fn`` as a vectorized hot path (see module docstring)."""
    HOT_PATHS[f"{fn.__module__}.{fn.__qualname__}"] = fn.__module__
    return fn
