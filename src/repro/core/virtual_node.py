"""Virtual nodes: capacity admission via scheduler semantics (paper §4.1).

For each TokenPool the Virtual Node Provider creates a *virtual node*
advertising extended resources that mirror pool capacity (token
throughput, KV GiB, concurrency).  Entitlement controllers create
*virtual lease pods* requesting specific token resources; the scheduler
binds a lease to the node iff allocatable capacity suffices, otherwise
the lease stays Pending and the entitlement is marked Degraded.

The lease pod consumes no compute — it exists solely to occupy capacity,
so two entitlements can never claim the same reserved tokens.  In the
paper this repurposes the Kubernetes scheduler (inheriting its
consistency and race handling); here we implement the same contract as
a deterministic in-process scheduler with transactional binds:

  * bind is atomic: either the full resource vector fits and is
    committed, or nothing is;
  * unbind returns capacity and triggers a rescheduling pass over the
    pending queue in FIFO order (K8s would re-queue pending pods);
  * capacity changes (autoscaling, replica failure) also trigger
    rescheduling, and may *preempt* bound leases in reverse-priority
    order when capacity shrinks below committed reservations.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.types import Resources


@dataclasses.dataclass
class LeasePod:
    """A virtual pod requesting token resources for one entitlement."""

    name: str
    entitlement: str
    request: Resources
    #: larger weight = more protected (evicted last on capacity shrink)
    protection_weight: float = 0.0
    bound: bool = False


@dataclasses.dataclass
class VirtualNode:
    """Synthetic node advertising a pool's capacity as extended resources."""

    name: str
    capacity: Resources
    allocated: Resources = dataclasses.field(default_factory=Resources.zero)

    def allocatable(self) -> Resources:
        return (self.capacity - self.allocated).clamp_nonneg()


class VirtualNodeProvider:
    """One virtual node per pool + the scheduler that binds leases."""

    def __init__(self) -> None:
        self._nodes: dict[str, VirtualNode] = {}
        self._leases: dict[str, LeasePod] = {}       # by lease name
        self._pending: list[str] = []                # FIFO of lease names
        #: bind/unbind event log (name, event) for tests & observability
        self.events: list[tuple[str, str]] = []

    # -- node lifecycle -----------------------------------------------------
    def create_node(self, pool: str, capacity: Resources) -> VirtualNode:
        node = VirtualNode(name=f"vnode-{pool}", capacity=capacity)
        self._nodes[pool] = node
        return node

    def node(self, pool: str) -> VirtualNode:
        return self._nodes[pool]

    def set_capacity(self, pool: str, capacity: Resources) -> list[str]:
        """Update node capacity (autoscale / replica failure).

        Returns the names of leases *preempted* because the new capacity
        cannot hold all bound reservations.  Preemption evicts the least
        protected leases first; then pending leases are rescheduled.
        """
        node = self._nodes[pool]
        node.capacity = capacity
        preempted = []
        # Evict least-protected bound leases until committed fits capacity.
        while not node.allocated.fits_within(node.capacity):
            bound = [l for l in self._leases.values()
                     if l.bound and self._pool_of(l) == pool]
            if not bound:
                break
            victim = min(bound, key=lambda l: (l.protection_weight, l.name))
            self._unbind(pool, victim)
            self._pending.append(victim.name)
            preempted.append(victim.name)
            self.events.append((victim.name, "preempted"))
        self._reschedule(pool)
        return preempted

    # -- lease lifecycle ----------------------------------------------------
    def submit(self, pool: str, lease: LeasePod) -> bool:
        """Create a lease pod; attempt to schedule it immediately.

        Returns True if bound, False if left Pending (⇒ Degraded)."""
        self._leases[lease.name] = lease
        lease._pool = pool  # type: ignore[attr-defined]
        if self._try_bind(pool, lease):
            return True
        self._pending.append(lease.name)
        return False

    def delete(self, lease_name: str) -> None:
        lease = self._leases.pop(lease_name, None)
        if lease is None:
            return
        pool = self._pool_of(lease)
        if lease.bound:
            self._unbind(pool, lease)
            self._reschedule(pool)
        elif lease_name in self._pending:
            self._pending.remove(lease_name)

    def resize(self, lease_name: str, request: Resources) -> bool:
        """Change a lease's resource request atomically (entitlement
        update).  Falls back to the old request if the new one doesn't
        fit; returns bound-status for the *new* request."""
        lease = self._leases[lease_name]
        pool = self._pool_of(lease)
        old = lease.request
        if lease.bound:
            self._unbind(pool, lease)
        lease.request = request
        if self._try_bind(pool, lease):
            self._reschedule(pool)
            return True
        # restore: try to re-bind the old request so a failed grow
        # doesn't lose an existing reservation
        lease.request = old
        if not self._try_bind(pool, lease):
            if lease.name not in self._pending:
                self._pending.append(lease.name)
        lease.request = request  # the *spec* keeps the new ask
        return False

    def is_bound(self, lease_name: str) -> bool:
        lease = self._leases.get(lease_name)
        return bool(lease and lease.bound)

    def pending(self) -> list[str]:
        return list(self._pending)

    # -- internals ------------------------------------------------------------
    def _pool_of(self, lease: LeasePod) -> str:
        return lease._pool  # type: ignore[attr-defined]

    def _try_bind(self, pool: str, lease: LeasePod) -> bool:
        node = self._nodes[pool]
        if not lease.request.fits_within(node.allocatable()):
            return False
        node.allocated = node.allocated + lease.request
        lease.bound = True
        self.events.append((lease.name, "bound"))
        return True

    def _unbind(self, pool: str, lease: LeasePod) -> None:
        node = self._nodes[pool]
        node.allocated = (node.allocated - lease.request).clamp_nonneg()
        lease.bound = False
        self.events.append((lease.name, "unbound"))

    def _reschedule(self, pool: str) -> None:
        """FIFO pass over pending leases (K8s scheduler queue)."""
        still_pending: list[str] = []
        for name in self._pending:
            lease = self._leases.get(name)
            if lease is None or self._pool_of(lease) != pool:
                if lease is not None:
                    still_pending.append(name)
                continue
            if not self._try_bind(pool, lease):
                still_pending.append(name)
        self._pending = still_pending
