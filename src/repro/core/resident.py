"""Resident control-plane state — the arrays ARE the source of truth.

Before this module the pool's control-plane state lived in Python
dicts (``EntitlementStatus`` objects, ``TokenBucket`` objects, demand
dicts) and every accounting tick / admission quantum re-built array
snapshots row by row — O(n) Python work per tick that dominates past
~10^4 entitlements.  :class:`ResidentStore` inverts the ownership:

  * one structure-of-arrays per pool holds every control-plane column
    — class/baseline/SLO statics, the Eq. 2–3 ``burst``/``debt``
    EWMAs, the accounting-window accumulators (window tokens, demand
    window, demand EWMA), KV / concurrency in use, the token-bucket
    ledger columns (level / rate / refill clock), and the
    observability counters;
  * columns are padded to a power-of-two capacity with a free-slot
    list, so entitlement churn RECYCLES rows instead of reshaping the
    arrays — the jit-compiled kernels see a stable shape and never
    retrace within a capacity bucket;
  * :class:`ResidentStatus` is a *view* over one row: it exposes the
    exact ``EntitlementStatus`` attribute surface, but every read and
    write goes straight to the columns (``pool.status[name]`` hands
    out these views — dicts are views, arrays are truth);
  * the kernel-facing float32 columns are mirrored as a cached device
    ``ControlState``; Python-side writes invalidate the cache, the
    tick re-adopts its own device outputs, so steady-state ticking
    uploads nothing row-by-row.

dtype discipline: columns feeding the f32 kernels (baselines, SLO,
burst, debt) are stored as float32 — numerically identical to the old
gather path, which cast the f64 status floats to f32 on every snapshot
(and scattered back ``float(f32)`` values).  Accumulator columns
(window/demand/bucket/KV) stay float64 so sequential accumulation
matches the scalar bookkeeping bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.control_plane import CLASS_CODES, ControlState, bucket_width
from repro.core.types import EntitlementState, EntitlementStatus, Resources

#: EntitlementState <-> int8 codes for the ``state_code`` column.
STATE_CODES: dict[EntitlementState, int] = {
    s: i for i, s in enumerate(EntitlementState)}
STATES: tuple[EntitlementState, ...] = tuple(EntitlementState)
_BOUND_CODE = STATE_CODES[EntitlementState.BOUND]

#: column name → dtype.  ``_F32_KERNEL`` columns feed the jit kernels
#: (device-mirrored); the rest are host-side truth.
_F32_KERNEL = ("baseline_tps", "baseline_kv", "baseline_conc", "slo_ms",
               "burst", "debt")
_COLUMNS: dict[str, np.dtype] = {
    "class_code": np.dtype(np.int32),
    "state_code": np.dtype(np.int8),
    "alive": np.dtype(bool),
    "bound": np.dtype(bool),
    **{c: np.dtype(np.float32) for c in _F32_KERNEL},
    # accounting accumulators (float64: sequential-accumulation parity
    # with the scalar bookkeeping)
    "window_tokens": np.dtype(np.float64),
    "measured_tps": np.dtype(np.float64),
    "kv_in_use": np.dtype(np.float64),
    "demand_window": np.dtype(np.float64),
    "demand_tps": np.dtype(np.float64),
    "eff_tps": np.dtype(np.float64),
    "eff_kv": np.dtype(np.float64),
    "eff_conc": np.dtype(np.float64),
    # token-bucket ledger columns (core.ledger.RowBucket views)
    "has_bucket": np.dtype(bool),
    "bucket_level": np.dtype(np.float64),
    "bucket_rate": np.dtype(np.float64),
    "bucket_refill": np.dtype(np.float64),
    "bucket_window": np.dtype(np.float64),
    # counters / observability
    "in_flight": np.dtype(np.int64),
    "resident": np.dtype(np.int64),
    "admitted_total": np.dtype(np.int64),
    "denied_total": np.dtype(np.int64),
    "denied_low_priority": np.dtype(np.int64),
    "completed_total": np.dtype(np.int64),
    "tokens_total": np.dtype(np.float64),
    "created_at": np.dtype(np.float64),
}

#: columns carried by the cached device ``ControlState`` mirror — any
#: host-side write to one of these MUST be followed by ``mark_dirty()``
#: (or adopt the kernel output via ``adopt_device``), else every later
#: admission kernel reads stale burst/debt.  Enforced statically by the
#: ``mirror-invalidation`` pass (``python -m repro.analysis``).
_MIRRORED = ("class_code", "bound") + _F32_KERNEL

#: qualnames allowed to write mirrored columns WITHOUT a trailing
#: ``mark_dirty()`` — ``adopt_device`` replaces the cache wholesale.
_SANCTIONED_MUTATORS = ("ResidentStore.adopt_device",)


def column_manifest() -> dict:
    """Machine-readable column contract for the static analyzer:
    column dtypes, the device-mirrored set, the f32 kernel-facing set,
    and the sanctioned mirror mutators.  The analyzer seeds the
    mirror-invalidation and dtype-discipline passes from this, so a
    new column is covered the moment it lands in ``_COLUMNS``."""
    return {
        "store": "ResidentStore",
        "module": "repro.core.resident",
        "columns": {name: str(dtype) for name, dtype in _COLUMNS.items()},
        "mirrored": list(_MIRRORED),
        "kernel_f32": list(_F32_KERNEL),
        "sanctioned_mutators": list(_SANCTIONED_MUTATORS),
    }


class ResidentStore:
    """Structure-of-arrays store for one pool's control-plane rows."""

    def __init__(self, capacity: int = 8) -> None:
        self.capacity = bucket_width(max(1, capacity))
        self.slot_of: dict[str, int] = {}
        self.name_of: list[Optional[str]] = [None] * self.capacity
        # LIFO free list: recycling reuses the most recently freed slot
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self.col: dict[str, np.ndarray] = {
            name: np.zeros(self.capacity, dtype)
            for name, dtype in _COLUMNS.items()}
        self._device: Optional[ControlState] = None
        self._live_slots: Optional[np.ndarray] = None
        self._live_names: Optional[list[str]] = None
        #: bumps whenever capacity grows (array identities change)
        self.generation = 0
        #: opt-in ``repro.core.ledger.LevelAudit`` (None = off); set by
        #: ``Ledger.enable_level_audit`` — sanctioned bucket_level
        #: mutators notify it so conservation checkers can diff
        self.level_audit = None

    # -- slot lifecycle -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.slot_of)

    def __contains__(self, name: str) -> bool:
        return name in self.slot_of

    def allocate(self, name: str) -> int:
        """Claim a free slot for ``name`` (growing capacity ×2 when
        full — the only event that changes array shapes, bounding jit
        variants to log2(N)).  The slot's columns are zeroed."""
        if name in self.slot_of:
            raise ValueError(f"entitlement {name!r} already resident")
        if not self._free:
            self._grow()
        slot = self._free.pop()
        self.slot_of[name] = slot
        self.name_of[slot] = name
        for arr in self.col.values():          # recycled slots start clean
            arr[slot] = 0
        self.col["alive"][slot] = True
        if self.level_audit is not None:
            self.level_audit.note("lifecycle", slot)
        self._membership_changed()
        return slot

    def release(self, name: str) -> int:
        """Free ``name``'s slot.  The row is zeroed (inert for every
        kernel mask: unbound, zero baselines/EWMAs) and pushed on the
        free list for recycling."""
        slot = self.slot_of.pop(name)
        self.name_of[slot] = None
        for arr in self.col.values():
            arr[slot] = 0
        self._free.append(slot)
        if self.level_audit is not None:
            self.level_audit.note("lifecycle", slot)
        self._membership_changed()
        return slot

    def _grow(self) -> None:
        old = self.capacity
        new = old * 2
        for name, arr in self.col.items():
            grown = np.zeros(new, arr.dtype)
            grown[:old] = arr
            self.col[name] = grown
        self.name_of.extend([None] * (new - old))
        self._free.extend(range(new - 1, old - 1, -1))
        self.capacity = new
        self.generation += 1
        self._membership_changed()

    def _membership_changed(self) -> None:
        self._device = None
        self._live_slots = None
        self._live_names = None

    def mark_dirty(self) -> None:
        """A kernel-facing column was written host-side: drop the
        cached device mirror (rebuilt lazily from the numpy columns)."""
        self._device = None

    # -- audit surface (public: chaos invariant checkers read these) ----------
    def row_accounting(self) -> dict:
        """Free-list / live-row closure snapshot: the invariant is
        ``live + free == capacity`` with the ``alive`` column agreeing
        on both counts."""
        return {
            "capacity": self.capacity,
            "live": len(self.slot_of),
            "free": len(self._free),
            "alive_rows": int(np.count_nonzero(self.col["alive"])),
        }

    def mirror_drift(self) -> dict[str, float]:
        """Max |device − host| per mirrored column, for the cached
        device mirror ONLY (empty dict when no mirror is cached — an
        invalidated mirror is coherent by definition).  Non-zero means
        a host write to a mirrored column skipped ``mark_dirty()``."""
        if self._device is None:
            return {}
        dev = self._device
        out: dict[str, float] = {}
        for name in _MIRRORED:
            host = self.col[name]
            mirror = np.asarray(getattr(dev, name))
            out[name] = float(np.max(np.abs(
                mirror.astype(np.float64) - host.astype(np.float64))))
        return out

    # -- live-row views (cached until membership changes) ---------------------
    def live_slots(self) -> np.ndarray:
        if self._live_slots is None:
            self._live_slots = np.flatnonzero(self.col["alive"])
        return self._live_slots

    def live_names(self) -> list[str]:
        """Live entitlement names in slot order (cached)."""
        if self._live_names is None:
            self._live_names = [self.name_of[s] for s in self.live_slots()]
        return self._live_names

    # -- device mirror --------------------------------------------------------
    def device_state(self) -> ControlState:
        """Kernel-facing ``ControlState`` over ALL slots (free slots are
        inert unbound rows).  Cached: rebuilt only after host-side
        writes; after a tick the kernel's own output state is adopted
        via :meth:`adopt_device`, so steady-state ticking re-uploads
        nothing."""
        if self._device is None:
            c = self.col
            self._device = ControlState(
                class_code=jnp.asarray(c["class_code"]),
                bound=jnp.asarray(c["bound"]),
                baseline_tps=jnp.asarray(c["baseline_tps"]),
                baseline_kv=jnp.asarray(c["baseline_kv"]),
                baseline_conc=jnp.asarray(c["baseline_conc"]),
                slo_ms=jnp.asarray(c["slo_ms"]),
                burst=jnp.asarray(c["burst"]),
                debt=jnp.asarray(c["debt"]),
            )
        return self._device

    def adopt_device(self, state: ControlState) -> None:
        """Adopt a tick's output state as the device mirror and sync the
        numpy burst/debt columns from it (two C-speed copies)."""
        self.col["burst"][:] = np.asarray(state.burst)
        self.col["debt"][:] = np.asarray(state.debt)
        self._device = state

    # -- row <-> EntitlementStatus --------------------------------------------
    def view(self, name: str) -> "ResidentStatus":
        return ResidentStatus(self, self.slot_of[name])

    def snapshot_status(self, name: str) -> EntitlementStatus:
        """Materialize a detached ``EntitlementStatus`` copy of a row
        (migration payloads, debugging)."""
        v = self.view(name)
        return EntitlementStatus(
            state=v.state, in_flight=v.in_flight, resident=v.resident,
            kv_bytes_in_use=v.kv_bytes_in_use, debt=v.debt, burst=v.burst,
            effective=v.effective, window_tokens=v.window_tokens,
            measured_tps=v.measured_tps, admitted_total=v.admitted_total,
            denied_total=v.denied_total,
            denied_low_priority=v.denied_low_priority,
            completed_total=v.completed_total, tokens_total=v.tokens_total,
            created_at=v.created_at)

    def load_status(self, slot: int, st) -> None:
        """Write an ``EntitlementStatus``-shaped object into a row
        (attach side of a migration)."""
        v = ResidentStatus(self, slot)
        v.state = st.state
        v.in_flight = st.in_flight
        v.resident = st.resident
        v.kv_bytes_in_use = st.kv_bytes_in_use
        v.debt = st.debt
        v.burst = st.burst
        v.effective = st.effective
        v.window_tokens = st.window_tokens
        v.measured_tps = st.measured_tps
        v.admitted_total = st.admitted_total
        v.denied_total = st.denied_total
        v.denied_low_priority = st.denied_low_priority
        v.completed_total = st.completed_total
        v.tokens_total = st.tokens_total
        v.created_at = st.created_at


def _col_property(col: str, py, *, dirty: bool = False):
    """Property accessing ``store.col[col][slot]`` coerced through
    ``py`` (float/int); ``dirty=True`` invalidates the device mirror
    on write (kernel-facing columns only)."""

    def fget(self):
        return py(self._store.col[col][self._slot])

    if dirty:
        def fset(self, value):
            self._store.col[col][self._slot] = value
            self._store.mark_dirty()
    else:
        def fset(self, value):
            self._store.col[col][self._slot] = value

    return property(fget, fset)


class ResidentStatus:
    """``EntitlementStatus``-compatible VIEW over one resident row.

    Same attribute surface, but reads and writes go straight to the
    store columns — mutating the view mutates the arrays the kernels
    consume, and vice versa.  ``pool.status[name]`` returns these.
    """

    __slots__ = ("_store", "_slot")

    def __init__(self, store: ResidentStore, slot: int) -> None:
        self._store = store
        self._slot = slot

    @property
    def slot(self) -> int:
        return self._slot

    # lifecycle state: code column + derived kernel ``bound`` mask
    @property
    def state(self) -> EntitlementState:
        return STATES[self._store.col["state_code"][self._slot]]

    @state.setter
    def state(self, value: EntitlementState) -> None:
        s, i = self._store, self._slot
        s.col["state_code"][i] = STATE_CODES[value]
        s.col["bound"][i] = STATE_CODES[value] == _BOUND_CODE
        s.mark_dirty()

    burst = _col_property("burst", float, dirty=True)
    debt = _col_property("debt", float, dirty=True)
    in_flight = _col_property("in_flight", int)
    resident = _col_property("resident", int)
    kv_bytes_in_use = _col_property("kv_in_use", float)
    window_tokens = _col_property("window_tokens", float)
    measured_tps = _col_property("measured_tps", float)
    admitted_total = _col_property("admitted_total", int)
    denied_total = _col_property("denied_total", int)
    denied_low_priority = _col_property("denied_low_priority", int)
    completed_total = _col_property("completed_total", int)
    tokens_total = _col_property("tokens_total", float)
    created_at = _col_property("created_at", float)

    @property
    def effective(self) -> Resources:
        s, i = self._store, self._slot
        return Resources(float(s.col["eff_tps"][i]),
                         float(s.col["eff_kv"][i]),
                         float(s.col["eff_conc"][i]))

    @effective.setter
    def effective(self, value: Resources) -> None:
        s, i = self._store, self._slot
        s.col["eff_tps"][i] = value.tokens_per_second
        s.col["eff_kv"][i] = value.kv_bytes
        s.col["eff_conc"][i] = value.concurrency

    def __repr__(self) -> str:  # debugging parity with the dataclass
        return (f"ResidentStatus(slot={self._slot}, state={self.state}, "
                f"in_flight={self.in_flight}, resident={self.resident}, "
                f"debt={self.debt}, burst={self.burst})")


@dataclasses.dataclass
class _DictView:
    """Read-only dict facade over a float64 column (legacy private
    surface: ``TokenPool._demand_tps`` used to be a plain dict; tests
    and tooling may still index it by name)."""

    store: ResidentStore
    column: str

    def __getitem__(self, name: str) -> float:
        return float(self.store.col[self.column][self.store.slot_of[name]])

    def get(self, name: str, default: float = 0.0) -> float:
        slot = self.store.slot_of.get(name)
        return default if slot is None else \
            float(self.store.col[self.column][slot])

    def __contains__(self, name: str) -> bool:
        return name in self.store.slot_of

    def __iter__(self):
        return iter(self.store.live_names())

    def __len__(self) -> int:
        return len(self.store.slot_of)

    def items(self):
        col = self.store.col[self.column]
        for name, slot in self.store.slot_of.items():
            yield name, float(col[slot])

    def keys(self):
        return list(self.store.slot_of)

    def values(self):
        return [v for _, v in self.items()]
