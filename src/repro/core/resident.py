"""Resident control-plane state — the arrays ARE the source of truth.

Before this module the pool's control-plane state lived in Python
dicts (``EntitlementStatus`` objects, ``TokenBucket`` objects, demand
dicts) and every accounting tick / admission quantum re-built array
snapshots row by row — O(n) Python work per tick that dominates past
~10^4 entitlements.  :class:`ResidentStore` inverts the ownership:

  * one structure-of-arrays per pool holds every control-plane column
    — class/baseline/SLO statics, the Eq. 2–3 ``burst``/``debt``
    EWMAs, the accounting-window accumulators (window tokens, demand
    window, demand EWMA), KV / concurrency in use, the token-bucket
    ledger columns (level / rate / refill clock), and the
    observability counters;
  * columns are padded to a power-of-two capacity with a free-slot
    list, so entitlement churn RECYCLES rows instead of reshaping the
    arrays — the jit-compiled kernels see a stable shape and never
    retrace within a capacity bucket;
  * :class:`ResidentStatus` is a *view* over one row: it exposes the
    exact ``EntitlementStatus`` attribute surface, but every read and
    write goes straight to the columns (``pool.status[name]`` hands
    out these views — dicts are views, arrays are truth);
  * the kernel-facing float32 columns are mirrored as a cached device
    ``ControlState``; Python-side writes invalidate the cache, the
    tick re-adopts its own device outputs, so steady-state ticking
    uploads nothing row-by-row.

dtype discipline: columns feeding the f32 kernels (baselines, SLO,
burst, debt) are stored as float32 — numerically identical to the old
gather path, which cast the f64 status floats to f32 on every snapshot
(and scattered back ``float(f32)`` values).  Accumulator columns
(window/demand/bucket/KV) stay float64 so sequential accumulation
matches the scalar bookkeeping bit for bit.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.control_plane import CLASS_CODES, ControlState, bucket_width
from repro.core.types import EntitlementState, EntitlementStatus, Resources

#: EntitlementState <-> int8 codes for the ``state_code`` column.
STATE_CODES: dict[EntitlementState, int] = {
    s: i for i, s in enumerate(EntitlementState)}
STATES: tuple[EntitlementState, ...] = tuple(EntitlementState)
_BOUND_CODE = STATE_CODES[EntitlementState.BOUND]

#: column name → dtype.  ``_F32_KERNEL`` columns feed the jit kernels
#: (device-mirrored); the rest are host-side truth.
_F32_KERNEL = ("baseline_tps", "baseline_kv", "baseline_conc", "slo_ms",
               "burst", "debt")
_COLUMNS: dict[str, np.dtype] = {
    "class_code": np.dtype(np.int32),
    "state_code": np.dtype(np.int8),
    "alive": np.dtype(bool),
    "bound": np.dtype(bool),
    **{c: np.dtype(np.float32) for c in _F32_KERNEL},
    # accounting accumulators (float64: sequential-accumulation parity
    # with the scalar bookkeeping)
    "window_tokens": np.dtype(np.float64),
    "measured_tps": np.dtype(np.float64),
    "kv_in_use": np.dtype(np.float64),
    "demand_window": np.dtype(np.float64),
    "demand_tps": np.dtype(np.float64),
    "eff_tps": np.dtype(np.float64),
    "eff_kv": np.dtype(np.float64),
    "eff_conc": np.dtype(np.float64),
    # token-bucket ledger columns (core.ledger.RowBucket views)
    "has_bucket": np.dtype(bool),
    "bucket_level": np.dtype(np.float64),
    "bucket_rate": np.dtype(np.float64),
    "bucket_refill": np.dtype(np.float64),
    "bucket_window": np.dtype(np.float64),
    # counters / observability
    "in_flight": np.dtype(np.int64),
    "resident": np.dtype(np.int64),
    "admitted_total": np.dtype(np.int64),
    "denied_total": np.dtype(np.int64),
    "denied_low_priority": np.dtype(np.int64),
    "completed_total": np.dtype(np.int64),
    "tokens_total": np.dtype(np.float64),
    "created_at": np.dtype(np.float64),
}

#: columns carried by the cached device ``ControlState`` mirror — any
#: host-side write to one of these MUST be followed by ``mark_dirty()``
#: (or adopt the kernel output via ``adopt_device``), else every later
#: admission kernel reads stale burst/debt.  Enforced statically by the
#: ``mirror-invalidation`` pass (``python -m repro.analysis``).
_MIRRORED = ("class_code", "bound") + _F32_KERNEL

#: qualnames allowed to write mirrored columns WITHOUT a trailing
#: ``mark_dirty()`` — ``adopt_device`` replaces the cache wholesale.
_SANCTIONED_MUTATORS = ("ResidentStore.adopt_device",)


def column_manifest() -> dict:
    """Machine-readable column contract for the static analyzer:
    column dtypes, the device-mirrored set, the f32 kernel-facing set,
    and the sanctioned mirror mutators.  The analyzer seeds the
    mirror-invalidation and dtype-discipline passes from this, so a
    new column is covered the moment it lands in ``_COLUMNS``."""
    return {
        "store": "ResidentStore",
        "module": "repro.core.resident",
        "columns": {name: str(dtype) for name, dtype in _COLUMNS.items()},
        "mirrored": list(_MIRRORED),
        "kernel_f32": list(_F32_KERNEL),
        "sanctioned_mutators": list(_SANCTIONED_MUTATORS),
    }


class ResidentStore:
    """Structure-of-arrays store for one pool's control-plane rows."""

    def __init__(self, capacity: int = 8) -> None:
        self.capacity = bucket_width(max(1, capacity))
        self.slot_of: dict[str, int] = {}
        self.name_of: list[Optional[str]] = [None] * self.capacity
        # LIFO free list: recycling reuses the most recently freed slot
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self.col: dict[str, np.ndarray] = {
            name: np.zeros(self.capacity, dtype)
            for name, dtype in _COLUMNS.items()}
        self._device: Optional[ControlState] = None
        self._live_slots: Optional[np.ndarray] = None
        self._live_names: Optional[list[str]] = None
        #: bumps whenever capacity grows (array identities change)
        self.generation = 0
        #: opt-in ``repro.core.ledger.LevelAudit`` (None = off); set by
        #: ``Ledger.enable_level_audit`` — sanctioned bucket_level
        #: mutators notify it so conservation checkers can diff
        self.level_audit = None

    # -- slot lifecycle -------------------------------------------------------
    def __len__(self) -> int:
        return len(self.slot_of)

    def __contains__(self, name: str) -> bool:
        return name in self.slot_of

    def allocate(self, name: str) -> int:
        """Claim a free slot for ``name`` (growing capacity ×2 when
        full — the only event that changes array shapes, bounding jit
        variants to log2(N)).  The slot's columns are zeroed."""
        if name in self.slot_of:
            raise ValueError(f"entitlement {name!r} already resident")
        if not self._free:
            self._grow()
        slot = self._free.pop()
        self.slot_of[name] = slot
        self.name_of[slot] = name
        for arr in self.col.values():          # recycled slots start clean
            arr[slot] = 0
        self.col["alive"][slot] = True
        if self.level_audit is not None:
            self.level_audit.note("lifecycle", slot)
        self._membership_changed()
        return slot

    def release(self, name: str) -> int:
        """Free ``name``'s slot.  The row is zeroed (inert for every
        kernel mask: unbound, zero baselines/EWMAs) and pushed on the
        free list for recycling."""
        slot = self.slot_of.pop(name)
        self.name_of[slot] = None
        for arr in self.col.values():
            arr[slot] = 0
        self._free.append(slot)
        if self.level_audit is not None:
            self.level_audit.note("lifecycle", slot)
        self._membership_changed()
        return slot

    def _grow(self) -> None:
        old = self.capacity
        new = old * 2
        for name, arr in self.col.items():
            grown = np.zeros(new, arr.dtype)
            grown[:old] = arr
            self.col[name] = grown
        self.name_of.extend([None] * (new - old))
        self._free.extend(range(new - 1, old - 1, -1))
        self.capacity = new
        self.generation += 1
        self._membership_changed()

    def _membership_changed(self) -> None:
        self._device = None
        self._live_slots = None
        self._live_names = None

    def mark_dirty(self) -> None:
        """A kernel-facing column was written host-side: drop the
        cached device mirror (rebuilt lazily from the numpy columns)."""
        self._device = None

    def mark_dirty_slot(self, slot: int) -> None:
        """Slot-granular mirror invalidation.  The flat store has no
        sub-mirror structure, so this is :meth:`mark_dirty`; the
        sharded store narrows it to the owning shard's block."""
        self.mark_dirty()

    # -- audit surface (public: chaos invariant checkers read these) ----------
    def row_accounting(self) -> dict:
        """Free-list / live-row closure snapshot: the invariant is
        ``live + free == capacity`` with the ``alive`` column agreeing
        on both counts."""
        return {
            "capacity": self.capacity,
            "live": len(self.slot_of),
            "free": len(self._free),
            "alive_rows": int(np.count_nonzero(self.col["alive"])),
        }

    def mirror_drift(self) -> dict[str, float]:
        """Max |device − host| per mirrored column, for the cached
        device mirror ONLY (empty dict when no mirror is cached — an
        invalidated mirror is coherent by definition).  Non-zero means
        a host write to a mirrored column skipped ``mark_dirty()``."""
        if self._device is None:
            return {}
        dev = self._device
        out: dict[str, float] = {}
        for name in _MIRRORED:
            host = self.col[name]
            mirror = np.asarray(getattr(dev, name))
            out[name] = float(np.max(np.abs(
                mirror.astype(np.float64) - host.astype(np.float64))))
        return out

    # -- live-row views (cached until membership changes) ---------------------
    def live_slots(self) -> np.ndarray:
        if self._live_slots is None:
            self._live_slots = np.flatnonzero(self.col["alive"])
        return self._live_slots

    def live_names(self) -> list[str]:
        """Live entitlement names in slot order (cached)."""
        if self._live_names is None:
            self._live_names = [self.name_of[s] for s in self.live_slots()]
        return self._live_names

    # -- device mirror --------------------------------------------------------
    def device_state(self) -> ControlState:
        """Kernel-facing ``ControlState`` over ALL slots (free slots are
        inert unbound rows).  Cached: rebuilt only after host-side
        writes; after a tick the kernel's own output state is adopted
        via :meth:`adopt_device`, so steady-state ticking re-uploads
        nothing."""
        if self._device is None:
            c = self.col
            self._device = ControlState(
                class_code=jnp.asarray(c["class_code"]),
                bound=jnp.asarray(c["bound"]),
                baseline_tps=jnp.asarray(c["baseline_tps"]),
                baseline_kv=jnp.asarray(c["baseline_kv"]),
                baseline_conc=jnp.asarray(c["baseline_conc"]),
                slo_ms=jnp.asarray(c["slo_ms"]),
                burst=jnp.asarray(c["burst"]),
                debt=jnp.asarray(c["debt"]),
            )
        return self._device

    def adopt_device(self, state: ControlState) -> None:
        """Adopt a tick's output state as the device mirror and sync the
        numpy burst/debt columns from it (two C-speed copies)."""
        self.col["burst"][:] = np.asarray(state.burst)
        self.col["debt"][:] = np.asarray(state.debt)
        self._device = state

    # -- row <-> EntitlementStatus --------------------------------------------
    def view(self, name: str) -> "ResidentStatus":
        return ResidentStatus(self, self.slot_of[name])

    def snapshot_status(self, name: str) -> EntitlementStatus:
        """Materialize a detached ``EntitlementStatus`` copy of a row
        (migration payloads, debugging)."""
        v = self.view(name)
        return EntitlementStatus(
            state=v.state, in_flight=v.in_flight, resident=v.resident,
            kv_bytes_in_use=v.kv_bytes_in_use, debt=v.debt, burst=v.burst,
            effective=v.effective, window_tokens=v.window_tokens,
            measured_tps=v.measured_tps, admitted_total=v.admitted_total,
            denied_total=v.denied_total,
            denied_low_priority=v.denied_low_priority,
            completed_total=v.completed_total, tokens_total=v.tokens_total,
            created_at=v.created_at)

    def load_status(self, slot: int, st) -> None:
        """Write an ``EntitlementStatus``-shaped object into a row
        (attach side of a migration)."""
        v = ResidentStatus(self, slot)
        v.state = st.state
        v.in_flight = st.in_flight
        v.resident = st.resident
        v.kv_bytes_in_use = st.kv_bytes_in_use
        v.debt = st.debt
        v.burst = st.burst
        v.effective = st.effective
        v.window_tokens = st.window_tokens
        v.measured_tps = st.measured_tps
        v.admitted_total = st.admitted_total
        v.denied_total = st.denied_total
        v.denied_low_priority = st.denied_low_priority
        v.completed_total = st.completed_total
        v.tokens_total = st.tokens_total
        v.created_at = st.created_at


def _state_block(state: ControlState, lo: int, hi: int) -> ControlState:
    """Device-side row slice of a ``ControlState`` (views, no upload)."""
    return ControlState(**{
        f.name: getattr(state, f.name)[lo:hi]
        for f in dataclasses.fields(ControlState)})


class ShardedResidentStore(ResidentStore):
    """:class:`ResidentStore` partitioned into ``n_shards`` equal
    contiguous row blocks — the host-side half of the sharded control
    plane (``core.shard_plane``).

    Same columns, same view objects, same ``slot_of`` surface — the
    facade changes WHERE work lands, not what callers see:

      * **per-shard free lists**: allocation picks the emptiest shard
        and recycles within it, so entitlement churn touches exactly
        one block and never crosses shards;
      * **block-granular mirror invalidation**: ``mark_dirty_slot``
        marks only the owning shard's block stale; ``device_state()``
        re-uploads dirty blocks and concatenates them with the cached
        clean ones device-side — attach/detach/migration of one row
        re-uploads ``capacity/n_shards`` rows, not the pool
        (``block_uploads`` / ``full_uploads`` / ``uploaded_rows``
        counters pin this in tests);
      * **slot stability**: shards are equal blocks of the CURRENT
        capacity.  Growth doubles the whole store — slots never move
        (every persistent view/row index stays valid) — and the
        shard boundaries are recomputed with the free lists rebuilt,
        an O(N) step on the already-O(N) grow path.

    ``n_shards`` must be a power of two so shard blocks align with
    the pow2 device blocks of any ``row_mesh`` of size ≤ ``n_shards``
    (the tree reductions are blocking-invariant, so ANY such mesh
    yields bit-identical decisions — mesh size is decoupled from the
    shard count)."""

    def __init__(self, capacity: int = 8, n_shards: int = 4) -> None:
        if n_shards < 1 or n_shards & (n_shards - 1):
            raise ValueError(
                f"n_shards must be a power of two, got {n_shards}")
        super().__init__(max(capacity, n_shards))
        self.n_shards = n_shards
        #: global free list retired: per-shard LIFO lists own recycling
        self._free = []
        self._shard_free: list[list[int]] = []
        self._rebuild_shard_free(list(range(self.capacity - 1, -1, -1)))
        #: per-shard device ``ControlState`` blocks (None = no block
        #: cache; concatenation of blocks == the full mirror)
        self._device_blocks: Optional[list[ControlState]] = None
        self._dirty_shards: set[int] = set()
        # upload observability (tests pin churn stays block-local)
        self.block_uploads = 0
        self.full_uploads = 0
        self.uploaded_rows = 0

    @property
    def shard_rows(self) -> int:
        return self.capacity // self.n_shards

    def shard_of(self, slot: int) -> int:
        return slot // self.shard_rows

    def shard_of_name(self, name: str) -> int:
        """Owning shard of a resident entitlement (routing surface)."""
        return self.shard_of(self.slot_of[name])

    def _rebuild_shard_free(self, free_desc: list[int]) -> None:
        """Rebuild per-shard LIFO free lists from a descending global
        free list (descending append ⇒ pop() yields ascending slots,
        matching the flat store's initial recycle order)."""
        rows = self.capacity // self.n_shards
        self._shard_free = [[] for _ in range(self.n_shards)]
        for slot in free_desc:
            self._shard_free[slot // rows].append(slot)

    def _pick_shard(self) -> Optional[int]:
        """Emptiest shard (ties → lowest id): balanced residency keeps
        per-device work even across the mesh."""
        best, best_free = None, 0
        for s, fl in enumerate(self._shard_free):
            if len(fl) > best_free:
                best, best_free = s, len(fl)
        return best

    # -- slot lifecycle (shard-local churn) -----------------------------------
    def allocate(self, name: str) -> int:
        if name in self.slot_of:
            raise ValueError(f"entitlement {name!r} already resident")
        shard = self._pick_shard()
        if shard is None:
            self._grow()
            shard = self._pick_shard()
        slot = self._shard_free[shard].pop()
        self.slot_of[name] = slot
        self.name_of[slot] = name
        for arr in self.col.values():          # recycled slots start clean
            arr[slot] = 0
        self.col["alive"][slot] = True
        if self.level_audit is not None:
            self.level_audit.note("lifecycle", slot)
        self._membership_changed_shard(slot)
        return slot

    def release(self, name: str) -> int:
        slot = self.slot_of.pop(name)
        self.name_of[slot] = None
        for arr in self.col.values():
            arr[slot] = 0
        self._shard_free[self.shard_of(slot)].append(slot)
        if self.level_audit is not None:
            self.level_audit.note("lifecycle", slot)
        self._membership_changed_shard(slot)
        return slot

    def _grow(self) -> None:
        old = self.capacity
        kept = [s for fl in self._shard_free for s in fl]
        super()._grow()                        # doubles arrays + capacity
        self._free = []
        # shard BOUNDARIES move (shard_rows doubled); slots do not —
        # rebuild the free lists under the new mapping
        self._rebuild_shard_free(
            sorted(kept + list(range(old, self.capacity)), reverse=True))

    def _membership_changed_shard(self, slot: int) -> None:
        """Shard-local flavor of ``_membership_changed``: live caches
        drop (they index the whole store) but the mirror goes stale
        only in the owning shard's block."""
        self._live_slots = None
        self._live_names = None
        self.mark_dirty_slot(slot)

    def _membership_changed(self) -> None:
        super()._membership_changed()
        self._device_blocks = None
        self._dirty_shards.clear()

    # -- block-granular device mirror -----------------------------------------
    def mark_dirty(self) -> None:
        self._device = None
        self._device_blocks = None
        self._dirty_shards.clear()

    def mark_dirty_slot(self, slot: int) -> None:
        if self._device is not None:
            # split the (clean) full mirror into blocks before any goes
            # stale — device-side slicing, no upload
            rows = self.shard_rows
            self._device_blocks = [
                _state_block(self._device, s * rows, (s + 1) * rows)
                for s in range(self.n_shards)]
            self._device = None
        if self._device_blocks is None:
            return                             # fully dirty: next build is full
        self._dirty_shards.add(self.shard_of(slot))

    def device_state(self) -> ControlState:
        if self._device is not None:
            return self._device
        if self._device_blocks is not None:
            rows = self.shard_rows
            c = self.col
            for s in sorted(self._dirty_shards):
                lo = s * rows
                self._device_blocks[s] = ControlState(
                    class_code=jnp.asarray(c["class_code"][lo:lo + rows]),
                    bound=jnp.asarray(c["bound"][lo:lo + rows]),
                    baseline_tps=jnp.asarray(
                        c["baseline_tps"][lo:lo + rows]),
                    baseline_kv=jnp.asarray(c["baseline_kv"][lo:lo + rows]),
                    baseline_conc=jnp.asarray(
                        c["baseline_conc"][lo:lo + rows]),
                    slo_ms=jnp.asarray(c["slo_ms"][lo:lo + rows]),
                    burst=jnp.asarray(c["burst"][lo:lo + rows]),
                    debt=jnp.asarray(c["debt"][lo:lo + rows]),
                )
            self.block_uploads += len(self._dirty_shards)
            self.uploaded_rows += rows * len(self._dirty_shards)
            self._dirty_shards.clear()
            blocks = self._device_blocks
            self._device = ControlState(**{
                f.name: jnp.concatenate(
                    [getattr(b, f.name) for b in blocks])
                for f in dataclasses.fields(ControlState)})
            return self._device
        state = super().device_state()         # full (re)build
        self.full_uploads += 1
        self.uploaded_rows += self.capacity
        return state

    def adopt_device(self, state: ControlState) -> None:
        super().adopt_device(state)
        self._device_blocks = None             # blocks stale; resliced lazily
        self._dirty_shards.clear()

    # -- audit surface --------------------------------------------------------
    def row_accounting(self) -> dict:
        return {
            "capacity": self.capacity,
            "live": len(self.slot_of),
            "free": sum(len(fl) for fl in self._shard_free),
            "alive_rows": int(np.count_nonzero(self.col["alive"])),
            "n_shards": self.n_shards,
            "shard_free": [len(fl) for fl in self._shard_free],
        }


def _col_property(col: str, py, *, dirty: bool = False):
    """Property accessing ``store.col[col][slot]`` coerced through
    ``py`` (float/int); ``dirty=True`` invalidates the device mirror
    on write (kernel-facing columns only)."""

    def fget(self):
        return py(self._store.col[col][self._slot])

    if dirty:
        def fset(self, value):
            self._store.col[col][self._slot] = value
            self._store.mark_dirty_slot(self._slot)
    else:
        def fset(self, value):
            self._store.col[col][self._slot] = value

    return property(fget, fset)


class ResidentStatus:
    """``EntitlementStatus``-compatible VIEW over one resident row.

    Same attribute surface, but reads and writes go straight to the
    store columns — mutating the view mutates the arrays the kernels
    consume, and vice versa.  ``pool.status[name]`` returns these.
    """

    __slots__ = ("_store", "_slot")

    def __init__(self, store: ResidentStore, slot: int) -> None:
        self._store = store
        self._slot = slot

    @property
    def slot(self) -> int:
        return self._slot

    # lifecycle state: code column + derived kernel ``bound`` mask
    @property
    def state(self) -> EntitlementState:
        return STATES[self._store.col["state_code"][self._slot]]

    @state.setter
    def state(self, value: EntitlementState) -> None:
        s, i = self._store, self._slot
        s.col["state_code"][i] = STATE_CODES[value]
        s.col["bound"][i] = STATE_CODES[value] == _BOUND_CODE
        s.mark_dirty_slot(i)

    burst = _col_property("burst", float, dirty=True)
    debt = _col_property("debt", float, dirty=True)
    in_flight = _col_property("in_flight", int)
    resident = _col_property("resident", int)
    kv_bytes_in_use = _col_property("kv_in_use", float)
    window_tokens = _col_property("window_tokens", float)
    measured_tps = _col_property("measured_tps", float)
    admitted_total = _col_property("admitted_total", int)
    denied_total = _col_property("denied_total", int)
    denied_low_priority = _col_property("denied_low_priority", int)
    completed_total = _col_property("completed_total", int)
    tokens_total = _col_property("tokens_total", float)
    created_at = _col_property("created_at", float)

    @property
    def effective(self) -> Resources:
        s, i = self._store, self._slot
        return Resources(float(s.col["eff_tps"][i]),
                         float(s.col["eff_kv"][i]),
                         float(s.col["eff_conc"][i]))

    @effective.setter
    def effective(self, value: Resources) -> None:
        s, i = self._store, self._slot
        s.col["eff_tps"][i] = value.tokens_per_second
        s.col["eff_kv"][i] = value.kv_bytes
        s.col["eff_conc"][i] = value.concurrency

    def __repr__(self) -> str:  # debugging parity with the dataclass
        return (f"ResidentStatus(slot={self._slot}, state={self.state}, "
                f"in_flight={self.in_flight}, resident={self.resident}, "
                f"debt={self.debt}, burst={self.burst})")


@dataclasses.dataclass
class _DictView:
    """Read-only dict facade over a float64 column (legacy private
    surface: ``TokenPool._demand_tps`` used to be a plain dict; tests
    and tooling may still index it by name)."""

    store: ResidentStore
    column: str

    def __getitem__(self, name: str) -> float:
        return float(self.store.col[self.column][self.store.slot_of[name]])

    def get(self, name: str, default: float = 0.0) -> float:
        slot = self.store.slot_of.get(name)
        return default if slot is None else \
            float(self.store.col[self.column][slot])

    def __contains__(self, name: str) -> bool:
        return name in self.store.slot_of

    def __iter__(self):
        return iter(self.store.live_names())

    def __len__(self) -> int:
        return len(self.store.slot_of)

    def items(self):
        col = self.store.col[self.column]
        for name, slot in self.store.slot_of.items():
            yield name, float(col[slot])

    def keys(self):
        return list(self.store.slot_of)

    def values(self):
        return [v for _, v in self.items()]
