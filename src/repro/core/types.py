"""Core datatypes for the token-pool control plane.

Faithful to the paper's §3 formalism:

- three schedulable resources per entitlement: token throughput ``lambda``
  (tokens/second), KV-cache capacity ``chi`` (bytes), concurrency ``r``
  (active sequences);
- five service classes (Table 1) with base weights 1000/1000/100/1/0.1;
- an entitlement state machine (Pending / Bound / Degraded / Expired).

Everything here is plain-Python and deterministic: no wall clock, no
randomness.  Time enters only through explicit ``now`` arguments.
"""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class ServiceClass(str, enum.Enum):
    """Paper Table 1.  Ordering here is the *protection* ordering: when
    reclaiming capacity, preemptible is evicted first, spot throttled
    next, elastic shrunk, dedicated/guaranteed never touched."""

    DEDICATED = "dedicated"
    GUARANTEED = "guaranteed"
    ELASTIC = "elastic"
    SPOT = "spot"
    PREEMPTIBLE = "preemptible"


#: Base priority weights w_kappa (paper Table 1).  The multi-order-of-
#: magnitude gaps ensure class dominates other priority factors.
CLASS_WEIGHT: dict[ServiceClass, float] = {
    ServiceClass.DEDICATED: 1000.0,
    ServiceClass.GUARANTEED: 1000.0,
    ServiceClass.ELASTIC: 100.0,
    ServiceClass.SPOT: 1.0,
    ServiceClass.PREEMPTIBLE: 0.1,
}

#: Reclamation order (first = reclaimed first).  Paper §3.2.
RECLAIM_ORDER: tuple[ServiceClass, ...] = (
    ServiceClass.PREEMPTIBLE,
    ServiceClass.SPOT,
    ServiceClass.ELASTIC,
)

#: Classes whose baseline is reserved and never reclaimed.
PROTECTED_CLASSES: frozenset[ServiceClass] = frozenset(
    {ServiceClass.DEDICATED, ServiceClass.GUARANTEED}
)

#: Classes allowed to burst above baseline (Table 1 "Burst" column).
BURST_CLASSES: frozenset[ServiceClass] = frozenset(
    {
        ServiceClass.DEDICATED,
        ServiceClass.ELASTIC,
        ServiceClass.SPOT,
        ServiceClass.PREEMPTIBLE,
    }
)

#: Classes that accumulate service debt (only elastic receives
#: compensatory allocation; paper §3.2).
DEBT_CLASSES: frozenset[ServiceClass] = frozenset({ServiceClass.ELASTIC})


class EntitlementState(str, enum.Enum):
    """Entitlement lifecycle (paper §4.1/§4.3).  Admission requires Bound."""

    PENDING = "Pending"      # created, lease pod not yet bound
    BOUND = "Bound"          # lease bound on the virtual node; admitting
    DEGRADED = "Degraded"    # insufficient pool capacity for the lease
    EXPIRED = "Expired"      # TTL elapsed / revoked


@dataclasses.dataclass(frozen=True)
class Resources:
    """The three schedulable resources (paper §3.1).

    ``tokens_per_second`` — λ: rate of token production.
    ``kv_bytes``          — χ: KV-cache capacity in bytes.
    ``concurrency``       — r: simultaneously active sequences.
    """

    tokens_per_second: float = 0.0
    kv_bytes: float = 0.0
    concurrency: float = 0.0

    def __add__(self, other: "Resources") -> "Resources":
        return Resources(
            self.tokens_per_second + other.tokens_per_second,
            self.kv_bytes + other.kv_bytes,
            self.concurrency + other.concurrency,
        )

    def __sub__(self, other: "Resources") -> "Resources":
        return Resources(
            self.tokens_per_second - other.tokens_per_second,
            self.kv_bytes - other.kv_bytes,
            self.concurrency - other.concurrency,
        )

    def scale(self, f: float) -> "Resources":
        return Resources(
            self.tokens_per_second * f, self.kv_bytes * f, self.concurrency * f
        )

    def fits_within(self, cap: "Resources", eps: float = 1e-9) -> bool:
        return (
            self.tokens_per_second <= cap.tokens_per_second + eps
            and self.kv_bytes <= cap.kv_bytes + eps
            and self.concurrency <= cap.concurrency + eps
        )

    def clamp_nonneg(self) -> "Resources":
        return Resources(
            max(0.0, self.tokens_per_second),
            max(0.0, self.kv_bytes),
            max(0.0, self.concurrency),
        )

    @staticmethod
    def zero() -> "Resources":
        return Resources(0.0, 0.0, 0.0)


@dataclasses.dataclass(frozen=True)
class QoS:
    """QoS block of a TokenEntitlement (paper §4.2)."""

    service_class: ServiceClass = ServiceClass.ELASTIC
    slo_target_ms: float = 1000.0


@dataclasses.dataclass(frozen=True)
class PriorityCoefficients:
    """α coefficients of Eq. 1 and the EWMA decays of Eqs. 2–3.

    Paper defaults: α_slo=2.0, α_burst=1.0, α_debt=4.0; γ_d=0.7 in Exp. 2.
    The clip bounds are anti-windup on top of the EWMA (paper §3.3 calls
    the decay itself anti-windup): the instantaneous gap is clipped to
    ±1 (one baseline's worth per tick) and accumulated debt saturates —
    credit from a transient overservice burst must not zero a tenant's
    priority (the debt factor stays ≥ 1 + α_debt·debt_min > 0).
    """

    alpha_slo: float = 2.0
    alpha_burst: float = 1.0
    alpha_debt: float = 4.0
    gamma_debt: float = 0.7
    gamma_burst: float = 0.7
    gap_clip: float = 1.0
    debt_min: float = -0.15
    debt_max: float = 2.0


@dataclasses.dataclass
class EntitlementSpec:
    """Declarative spec (mirrors the TokenEntitlement CRD, paper §4.2)."""

    name: str
    tenant_id: str
    pool: str
    qos: QoS
    baseline: Resources
    api_keys: tuple[str, ...] = ()
    ttl_s: Optional[float] = None   # None = no expiry


@dataclasses.dataclass
class EntitlementStatus:
    """Mutable per-entitlement control-plane state (stored in the
    StateStore; the paper keeps this in Redis)."""

    state: EntitlementState = EntitlementState.PENDING
    in_flight: int = 0                       # admitted, not yet completed
    resident: int = 0                        # sequences with KV resident
    #                                          on decode workers (§3.1 r)
    kv_bytes_in_use: float = 0.0             # resident KV attribution
    debt: float = 0.0                        # d_e, Eq. 2
    burst: float = 0.0                       # b_e, EWMA of Eq. 3
    effective: Resources = dataclasses.field(default_factory=Resources.zero)
    # Rolling token-throughput measurement (tokens completed in the
    # current accounting window); converted to tok/s by the pool tick.
    window_tokens: float = 0.0
    measured_tps: float = 0.0
    # Counters for observability / the experiments.
    admitted_total: int = 0
    denied_total: int = 0
    denied_low_priority: int = 0
    completed_total: int = 0
    tokens_total: float = 0.0
    created_at: float = 0.0


@dataclasses.dataclass(frozen=True)
class ScalingBounds:
    min_replicas: int = 1
    max_replicas: int = 10


@dataclasses.dataclass
class PoolSpec:
    """TokenPool CRD (paper §4.2): a logical capacity pool bound to a
    model backend with autoscaling bounds."""

    name: str
    model: str
    scaling: ScalingBounds = dataclasses.field(default_factory=ScalingBounds)
    #: capacity contributed by ONE backend replica
    per_replica: Resources = dataclasses.field(
        default_factory=lambda: Resources(240.0, 16 * (1 << 30), 16)
    )
    coefficients: PriorityCoefficients = dataclasses.field(
        default_factory=PriorityCoefficients
    )
    #: default applied when a request omits max_tokens (admission check 2)
    default_max_tokens: int = 256
    #: EWMA window (seconds) for throughput measurement
    accounting_interval_s: float = 1.0
    #: relative slack on the contention threshold (check 5): admit iff
    #: w > (1 − slack)·threshold.  The default 0 keeps the paper's
    #: strict "must exceed" semantics (an entitlement that already sets
    #: the pool minimum cannot add work while others wait); operators
    #: can add slack to soften same-class self-competition.
    admission_slack: float = 0.0
    #: pin ℓ̄* to a constant instead of the live mean over bound members
    #: (the paper's Exp. 2 keeps ℓ̄*=15250 ms after a third tenant joins)
    fixed_avg_slo_ms: Optional[float] = None
    #: token-bucket window (seconds of λ̂ of burst credit).  Commercial
    #: tokens-per-minute semantics (paper §1 [7]) ⇒ 60; short windows
    #: make check (4) bind before the contention check (5).
    bucket_window_s: float = 4.0
    #: time constant τ of the dt-aware demand EWMA: each tick retains
    #: exp(−dt/τ) of the previous estimate (α = 1 − exp(−dt/τ)), so the
    #: smoothing horizon no longer depends on the tick rate.  None (the
    #: default) uses τ = accounting_interval_s / ln 2 — a tick at the
    #: nominal interval then retains exactly ½, the historical fixed
    #: blend.
    demand_tau_s: Optional[float] = None
    #: cap on retained ``TickRecord`` history (``TokenPool.history`` is
    #: a deque(maxlen=...)); None = unbounded.  Long-running
    #: deployments tick forever — an unbounded history is a slow leak.
    history_maxlen: Optional[int] = 4096
    #: partition the resident rows into this many shards (pow2) — the
    #: pool then uses ``ShardedResidentStore`` (shard-local churn,
    #: block-granular mirror uploads) and its tick/admission kernels
    #: dispatch over a ``shard_map`` row mesh whenever ≥2 devices are
    #: visible (``core.shard_plane``; decisions are bit-identical to
    #: the single-device kernels).  None/1 keeps the flat store.
    shards: Optional[int] = None


@dataclasses.dataclass(frozen=True)
class AdmissionRequest:
    """What the gateway presents to admission control for one request."""

    entitlement: str
    input_tokens: int
    max_tokens: Optional[int]            # None → pool default applied
    arrival_s: float
    request_id: str = ""
    #: per-token KV bytes of the pool's model (c = 2·L·H_kv·d_h·b)
    kv_bytes_per_token: float = 0.0


class DenyReason(str, enum.Enum):
    NOT_BOUND = "entitlement_not_bound"
    CONCURRENCY = "concurrency_limit"
    TOKEN_BUDGET = "token_budget"
    LOW_PRIORITY = "low_priority"
    POOL_UNAVAILABLE = "pool_unavailable"


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    admitted: bool
    reason: Optional[DenyReason] = None
    #: seconds the client should wait before retrying (429 Retry-After)
    retry_after_s: Optional[float] = None
    #: priority at decision time, for observability
    priority: float = 0.0
    #: token budget charged on admit (input + effective max_tokens)
    charged_tokens: int = 0
    effective_max_tokens: int = 0


def kv_bytes_per_token(
    num_layers: int, kv_heads: int, head_dim: int, bytes_per_elem: int = 2
) -> float:
    """c = 2 · L · H_kv · d_h · b   (paper §3.1)."""
    return 2.0 * num_layers * kv_heads * head_dim * bytes_per_elem


def max_concurrency(kv_budget_bytes: float, context_len: int, c: float) -> int:
    """r_max = floor(χ_gpu / (S·c))   (paper §3.1)."""
    denom = context_len * c
    if denom <= 0:
        return 0
    return int(kv_budget_bytes // denom)
