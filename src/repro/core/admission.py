"""Admission control — the paper's §4.3 five-check pipeline.

The auth service intercepts every request before it reaches the
backend.  Checks run in order; a failing check short-circuits:

  1. entitlement state must be Bound;
  2. output-length bound: a pool default is applied if the request
     omits max_tokens (capacity planning);
  3. concurrency: in-flight < r_e;
  4. token budget: (input + max_tokens) must fit the entitlement's
     remaining throughput allocation (token bucket funded at λ̂_e);
     KV headroom ((input + max_tokens)·c ≤ χ_e − in-use) is enforced
     here too, folding the paper's χ resource into the same check;
  5. pool contention: when the pool is saturated, the request's
     priority w_e must not fall below the admission threshold (the
     minimum priority among currently-admitted requests).

Rejections produce HTTP-429 semantics with a Retry-After hint derived
from the token bucket refill time (budget denials) or a class-scaled
backoff (priority denials).

This scalar pipeline is the per-request fallback and the DECISION
ORACLE for the batched hot path: ``vectorized.admit_quantum`` replays
these five checks for a whole scheduling quantum in one fused
dispatch (``Gateway.handle_quantum``), and
``tests/test_admit_quantum.py`` / ``tests/test_gateway_quantum.py``
pin the two decision-identical — any semantic change here must be
mirrored in the kernel.
"""
from __future__ import annotations

import numpy as np

from repro.core.ledger import Charge
from repro.core.pool import InFlight, TokenPool
from repro.core.types import (
    PROTECTED_CLASSES,
    AdmissionDecision,
    AdmissionRequest,
    DenyReason,
    EntitlementState,
    ServiceClass,
)


class AdmissionController:
    """Stateless decision logic over a TokenPool's state."""

    def __init__(self, pool: TokenPool) -> None:
        self.pool = pool

    def decide(self, req: AdmissionRequest) -> AdmissionDecision:
        pool = self.pool
        espec = pool.entitlements.get(req.entitlement)
        if espec is None:
            return AdmissionDecision(False, DenyReason.NOT_BOUND,
                                     retry_after_s=None)
        st = pool.status[req.entitlement]
        now = req.arrival_s

        # (1) entitlement state -------------------------------------------------
        if st.state != EntitlementState.BOUND:
            dec = AdmissionDecision(False, DenyReason.NOT_BOUND,
                                    retry_after_s=5.0)
            pool.register_deny(req.entitlement, 0.0, low_priority=False)
            return dec

        # (2) output-length bound ------------------------------------------------
        max_tokens = (req.max_tokens if req.max_tokens is not None
                      else pool.spec.default_max_tokens)
        budget_tokens = req.input_tokens + max_tokens
        kv_need = budget_tokens * req.kv_bytes_per_token

        # (3) concurrency limit ---------------------------------------------------
        # counts RESIDENT sequences (KV on decode workers, §3.1) — an
        # admitted-but-queued request holds no KV and no decode slot.
        # Burst-capable classes (Table 1) may exceed r_e while the pool
        # has idle slots: the concurrency *burst dimension* of the
        # work-conserving backfill.  The overage shows up in b_e (Eq. 3)
        # and progressively lowers their priority.
        from repro.core.types import BURST_CLASSES
        r_limit = espec.baseline.concurrency
        if espec.qos.service_class is ServiceClass.SPOT and r_limit <= 0:
            # spot with no explicit limit: bounded by pool capacity
            r_limit = pool.capacity().concurrency
        if r_limit > 0 and st.resident >= r_limit:
            burst_ok = (espec.qos.service_class in BURST_CLASSES
                        and pool.has_free_slots()
                        and not pool.contended())
            if not burst_ok:
                dec = AdmissionDecision(
                    False, DenyReason.CONCURRENCY,
                    retry_after_s=self._concurrency_backoff(
                        req.entitlement),
                    effective_max_tokens=max_tokens)
                pool.register_deny(req.entitlement, float(budget_tokens),
                                   low_priority=False)
                return dec

        # (4) token budget (+ KV headroom) ---------------------------------------
        bucket = pool.ledger.ensure(
            req.entitlement, st.effective.tokens_per_second
            or espec.baseline.tokens_per_second, now)
        if not bucket.can_afford(budget_tokens, now):
            retry = pool.ledger.retry_after(req.entitlement,
                                            budget_tokens, now)
            dec = AdmissionDecision(
                False, DenyReason.TOKEN_BUDGET,
                retry_after_s=min(retry, 60.0),
                effective_max_tokens=max_tokens)
            pool.register_deny(req.entitlement, float(budget_tokens),
                               low_priority=False)
            return dec
        chi_limit = espec.baseline.kv_bytes
        if chi_limit > 0 and st.kv_bytes_in_use + kv_need > chi_limit:
            dec = AdmissionDecision(
                False, DenyReason.TOKEN_BUDGET, retry_after_s=1.0,
                effective_max_tokens=max_tokens)
            pool.register_deny(req.entitlement, float(budget_tokens),
                               low_priority=False)
            return dec

        # (5) pool contention ------------------------------------------------------
        # Applies to burst classes only: "guaranteed requests are never
        # rejected (within their concurrency limits)" (§5.2) — protected
        # classes are shielded by their reservations and checks 1–4.
        # The comparison is STRICT ("must exceed the threshold", §4.3):
        # an entitlement whose requests already set the pool minimum
        # cannot push more work into a contended pool — this is what
        # directs throttling at the lowest-priority tenant.
        w = pool.priority(req.entitlement)
        shielded = espec.qos.service_class in PROTECTED_CLASSES
        if pool.contended() and not shielded:
            threshold = (pool.admission_threshold()
                         * (1.0 - pool.spec.admission_slack))
            if w <= threshold:
                dec = AdmissionDecision(
                    False, DenyReason.LOW_PRIORITY,
                    retry_after_s=self._priority_backoff(w, threshold),
                    priority=w, effective_max_tokens=max_tokens)
                pool.register_deny(req.entitlement, float(budget_tokens),
                                   low_priority=True)
                return dec

        # admitted: charge the bucket, register in-flight -----------------------
        charge = Charge(request_id=req.request_id,
                        entitlement=req.entitlement,
                        charged_tokens=float(budget_tokens),
                        input_tokens=req.input_tokens,
                        max_tokens=max_tokens,
                        admitted_at=now)
        if not pool.ledger.charge(charge, now):   # raced the refill window
            dec = AdmissionDecision(False, DenyReason.TOKEN_BUDGET,
                                    retry_after_s=1.0,
                                    effective_max_tokens=max_tokens)
            pool.register_deny(req.entitlement, float(budget_tokens),
                               low_priority=False)
            return dec
        pool.register_admit(
            InFlight(request_id=req.request_id,
                     entitlement=req.entitlement,
                     priority=w,
                     kv_bytes=kv_need,
                     charged_tokens=budget_tokens,
                     admitted_at=now),
            demand_tokens=float(budget_tokens))
        return AdmissionDecision(True, priority=w,
                                 charged_tokens=budget_tokens,
                                 effective_max_tokens=max_tokens)

    # -- retry hints -------------------------------------------------------------
    def _concurrency_backoff(self, entitlement: str) -> float:
        """Expected time for one slot to free: tokens outstanding / rate.

        Outstanding tokens are one masked sum over the request table's
        owner/charged columns — not a walk of every in-flight record."""
        pool = self.pool
        st = pool.status[entitlement]
        rate = max(1e-6, st.effective.tokens_per_second
                   or pool.entitlements[entitlement]
                   .baseline.tokens_per_second or 1.0)
        c = pool.table.col
        slot = pool.store.slot_of.get(entitlement)
        if slot is None:
            outstanding = 0
        else:
            mask = c["has_record"] & (c["owner"] == slot)
            outstanding = int(np.sum(c["rec_charged"][mask]))
        per_slot = outstanding / max(1, st.in_flight)
        return min(30.0, max(0.25, per_slot / rate))

    def _priority_backoff(self, w: float, threshold: float) -> float:
        """Lower-priority requests back off longer (graceful degradation)."""
        ratio = max(1.0, threshold / max(w, 1e-6))
        return min(30.0, 0.5 * ratio ** 0.5)
