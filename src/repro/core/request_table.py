"""Resident request lifecycle state — rows for in-flight requests.

PR 5's :class:`~repro.core.resident.ResidentStore` made the
*entitlement* control-plane columns the source of truth; this module
does the same for the *request* lifecycle.  Before it, every admitted
request lived as an ``InFlight`` dataclass in ``pool.in_flight`` and a
``Charge`` dataclass in ``ledger._charges`` — two dicts of per-request
Python objects that made charges, completions and evictions scatter
one request at a time (why ``Gateway.handle_quantum`` used to LOSE to
the scalar loop at 1024 req/quantum despite a ~29× faster admission
kernel).

:class:`RequestTable` is one structure-of-arrays per pool:

  * each row unifies the two halves of a request's lifecycle — the
    admission **record** (owner entitlement slot, priority, KV bytes,
    charged tokens, resident flag, admit clock) and the ledger
    **charge** (charged/input/max tokens, charge clock) — under one
    request-id keyed slot;
  * columns are padded to a power-of-two capacity with a LIFO free
    list, so request churn RECYCLES rows instead of reshaping arrays
    (rows on the free list are all-zero — release zeroes eagerly so
    the admission hot path never zeroes per row);
  * :class:`InFlightRow` is a *view* over one row with the exact
    ``InFlight`` attribute surface, and :class:`InFlightMap` is the
    dict facade behind ``pool.in_flight`` — dicts are views, arrays
    are truth;
  * the batched lifecycle ops (``TokenPool.settle_rows`` /
    ``evict_rows`` / ``register_admit_batch`` and
    ``Ledger.charge_rows``) are masked scatter-adds over these columns
    — O(batch) numpy instead of O(batch) Python object bookkeeping.

dtype discipline mirrors the store: every accumulator that feeds the
scalar bookkeeping is float64/int64, so the batched row-ops match the
retained per-request oracle (``on_complete`` / ``on_evict``) bit for
bit.  The record half and the charge half keep separate owner columns
(``owner`` vs ``ch_owner``): the legacy dicts were independent, and
the parity oracle allows a record and a charge for the same request id
to name different entitlements.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np

from repro.core.control_plane import bucket_width
from repro.core.ledger import Charge
from repro.core.markers import hot_path


@dataclasses.dataclass
class InFlight:
    """One admitted, not-yet-completed request.

    The dataclass is the MATERIALIZED form: detached payloads
    (migrations, ``on_complete`` return values) and test fixtures.
    Live requests are rows of :class:`RequestTable`, handed out as
    :class:`InFlightRow` views with this exact attribute surface."""

    request_id: str
    entitlement: str
    priority: float
    kv_bytes: float
    charged_tokens: int
    admitted_at: float
    resident: bool = False       # dispatched to a decode worker
    #: (pool, entitlement) of the route leg the client PREFERRED when
    #: this request was admitted by a later (spill) leg — None when the
    #: request was served by its first leg.  Drives per-request
    #: cross-pool debt transfer on completion
    #: (``PoolManager.transfer_spill_debt``).
    spill_from: Optional[tuple] = None
    #: actual settled token cost (input + actual output), stamped by
    #: ``on_complete`` so callers can attribute service without
    #: re-reading the ledger charge (already popped by then)
    settled_tokens: float = 0.0


#: column name → dtype.  ``has_record``/``has_charge`` gate the two
#: lifecycle halves; a row dies when both are clear.
_COLUMNS: dict[str, np.dtype] = {
    # admission record half (pool.in_flight)
    "has_record": np.dtype(bool),
    "owner": np.dtype(np.int32),          # entitlement slot in the store
    "priority": np.dtype(np.float64),
    "kv_bytes": np.dtype(np.float64),
    "rec_charged": np.dtype(np.int64),    # InFlight.charged_tokens
    "rec_admitted": np.dtype(np.float64),
    "resident": np.dtype(bool),
    "settled": np.dtype(np.float64),
    # ledger charge half (ledger outstanding charges)
    "has_charge": np.dtype(bool),
    "ch_owner": np.dtype(np.int32),
    "charged": np.dtype(np.float64),      # Charge.charged_tokens
    "input_tokens": np.dtype(np.int64),
    "max_tokens": np.dtype(np.int64),
    "ch_admitted": np.dtype(np.float64),
}


def column_manifest() -> dict:
    """Machine-readable column contract for the static analyzer (the
    request-table twin of ``resident.column_manifest``).  The table has
    no cached device mirror today — ``mirrored`` is empty — but the
    moment a column is listed there, every un-invalidated host write to
    it becomes a ``mirror-invalidation`` finding."""
    return {
        "store": "RequestTable",
        "module": "repro.core.request_table",
        "columns": {name: str(dtype) for name, dtype in _COLUMNS.items()},
        "mirrored": [],
        "kernel_f32": [],
        "sanctioned_mutators": [],
    }


class RequestTable:
    """Structure-of-arrays store for one pool's in-flight requests."""

    def __init__(self, store, capacity: int = 8) -> None:
        #: the pool's ResidentStore — owner columns index ITS slots,
        #: and entitlement names resolve through its ``name_of``
        self.store = store
        self.capacity = bucket_width(max(1, capacity))
        self.slot_of: dict[str, int] = {}
        self.rid_of: list[Optional[str]] = [None] * self.capacity
        #: per-row spill leg (rarely non-None → Python side list, not
        #: a column; follows record-half lifetime)
        self.spill_from: list[Optional[tuple]] = [None] * self.capacity
        # LIFO free list: recycling reuses the most recently freed slot
        self._free: list[int] = list(range(self.capacity - 1, -1, -1))
        self.col: dict[str, np.ndarray] = {
            name: np.zeros(self.capacity, dtype)
            for name, dtype in _COLUMNS.items()}
        #: live admission records (NOT rows: a charge-only row does not
        #: count toward ``len(pool.in_flight)``)
        self.n_records = 0
        #: bumps whenever capacity grows (array identities change)
        self.generation = 0

    # -- audit surface (public: chaos invariant checkers read these) ----------
    def row_accounting(self) -> dict:
        """Free-list / live-row closure snapshot: the invariant is
        ``rows + free == capacity``, with record rows a subset of live
        rows (``n_records`` counts record halves only)."""
        return {
            "capacity": self.capacity,
            "rows": len(self.slot_of),
            "free": len(self._free),
            "records": self.n_records,
            "record_rows": int(np.count_nonzero(self.col["has_record"])),
            "charge_rows": int(np.count_nonzero(self.col["has_charge"])),
        }

    # -- slot lifecycle -------------------------------------------------------
    def ensure_slot(self, request_id: str) -> int:
        """Row slot for ``request_id``, allocating one if needed.
        Allocation does NOT touch columns: rows on the free list are
        already all-zero (zeroed at release), which keeps the batched
        admit path free of per-row clearing."""
        slot = self.slot_of.get(request_id)
        if slot is None:
            if not self._free:
                self._grow()
            slot = self._free.pop()
            self.slot_of[request_id] = slot
            self.rid_of[slot] = request_id
        return slot

    @hot_path
    def ensure_slots(self, request_ids: list) -> np.ndarray:
        """Batched :meth:`ensure_slot`: one growth check, LIFO tail
        allocation, C-speed dict updates.  Known ids resolve to their
        existing rows; allocation order matches the scalar loop (the
        free-list tail is handed out in pop order).  Duplicate unknown
        ids fall back to the scalar loop so both occurrences land on
        one row."""
        n = len(request_ids)
        if not self.slot_of:             # empty table: all ids are new
            hits = [None] * n
            misses = n
        else:
            get = self.slot_of.get
            hits = [get(r) for r in request_ids]
            misses = hits.count(None)
        if misses == 0:
            return np.asarray(hits, np.int64)
        missing = request_ids if misses == n else \
            [r for r, s in zip(request_ids, hits) if s is None]
        if misses > 1 and len(set(missing)) != misses:
            return np.fromiter(
                (self.ensure_slot(r) for r in request_ids),
                np.int64, count=n)
        while len(self._free) < misses:
            self._grow()
        tail = self._free[-misses:]
        del self._free[-misses:]
        tail.reverse()                   # sequential pop() order
        self.slot_of.update(zip(missing, tail))
        rid_of = self.rid_of
        for r, s in zip(missing, tail):
            rid_of[s] = r
        if misses == n:
            return np.asarray(tail, np.int64)
        it = iter(tail)
        return np.asarray([next(it) if s is None else s for s in hits],
                          np.int64)

    def release(self, slot: int) -> None:
        """Free one row: zero every column (the free-list invariant)
        and push the slot for LIFO recycling."""
        if self.col["has_record"][slot]:
            self.n_records -= 1
        for arr in self.col.values():
            arr[slot] = 0
        rid = self.rid_of[slot]
        del self.slot_of[rid]
        self.rid_of[slot] = None
        self.spill_from[slot] = None
        self._free.append(slot)

    @hot_path
    def release_rows(self, slots: np.ndarray) -> None:
        """Batched :meth:`release` — column zeroing is one fancy-index
        write per column; the free list extends in iteration order, so
        future allocation order matches a scalar release loop."""
        c = self.col
        self.n_records -= int(np.count_nonzero(c["has_record"][slots]))
        for arr in c.values():
            arr[slots] = 0
        rid_of, spill = self.rid_of, self.spill_from
        slot_of = self.slot_of
        for s in slots.tolist():
            del slot_of[rid_of[s]]
            rid_of[s] = None
            spill[s] = None
        self._free.extend(slots.tolist())

    def _grow(self) -> None:
        old = self.capacity
        new = old * 2
        for name, arr in self.col.items():
            grown = np.zeros(new, arr.dtype)
            grown[:old] = arr
            self.col[name] = grown
        self.rid_of.extend([None] * (new - old))
        self.spill_from.extend([None] * (new - old))
        self._free.extend(range(new - 1, old - 1, -1))
        self.capacity = new
        self.generation += 1

    # -- record half ----------------------------------------------------------
    def put_record(self, rec) -> int:
        """Write an ``InFlight``-shaped object into its row (allocating
        or completing a charge-only row).  Owner resolves through the
        store — the entitlement must be resident."""
        slot = self.ensure_slot(rec.request_id)
        c = self.col
        if not c["has_record"][slot]:
            self.n_records += 1
        c["has_record"][slot] = True
        c["owner"][slot] = self.store.slot_of[rec.entitlement]
        c["priority"][slot] = rec.priority
        c["kv_bytes"][slot] = rec.kv_bytes
        c["rec_charged"][slot] = rec.charged_tokens
        c["rec_admitted"][slot] = rec.admitted_at
        c["resident"][slot] = rec.resident
        c["settled"][slot] = rec.settled_tokens
        self.spill_from[slot] = rec.spill_from
        return slot

    @hot_path
    def put_records(self, recs: list, owners: np.ndarray) -> np.ndarray:
        """One admission quantum's records as batched column writes
        (``owners`` are pre-resolved entitlement slots, aligned with
        ``recs``).  Returns the row slots."""
        n = len(recs)
        slots = self.ensure_slots([r.request_id for r in recs])
        c = self.col
        fresh = ~c["has_record"][slots]
        self.n_records += int(np.count_nonzero(fresh))
        c["has_record"][slots] = True
        c["owner"][slots] = owners
        c["priority"][slots] = np.fromiter(
            (r.priority for r in recs), np.float64, count=n)
        c["kv_bytes"][slots] = np.fromiter(
            (r.kv_bytes for r in recs), np.float64, count=n)
        c["rec_charged"][slots] = np.fromiter(
            (r.charged_tokens for r in recs), np.int64, count=n)
        c["rec_admitted"][slots] = np.fromiter(
            (r.admitted_at for r in recs), np.float64, count=n)
        spill = self.spill_from
        for s, r in zip(slots.tolist(), recs):
            if r.resident:
                c["resident"][s] = True
            if r.settled_tokens:
                c["settled"][s] = r.settled_tokens
            spill[s] = r.spill_from
        return slots

    @hot_path
    def admit_rows(self, request_ids: list, owners: np.ndarray,
                   kv_bytes: np.ndarray, charged_tokens: np.ndarray,
                   admitted_at: float,
                   slots: Optional[np.ndarray] = None) -> np.ndarray:
        """Array-native record insertion — the gateway quantum path
        (no per-request ``InFlight`` objects).  Rows start non-resident
        with no spill leg; the caller tags spill legs afterwards.
        ``slots`` skips the id resolution when the caller already holds
        the rows (the quantum path reuses the charge rows).  Returns
        the row slots."""
        if slots is None:
            slots = self.ensure_slots(request_ids)
        c = self.col
        fresh = ~c["has_record"][slots]
        self.n_records += int(np.count_nonzero(fresh))
        c["has_record"][slots] = True
        c["owner"][slots] = owners
        c["kv_bytes"][slots] = kv_bytes
        c["rec_charged"][slots] = charged_tokens
        c["rec_admitted"][slots] = admitted_at
        return slots

    def materialize_record(self, slot: int) -> InFlight:
        """Detached ``InFlight`` copy of one row's record half
        (completion return values, migration payloads — the row is
        about to be recycled)."""
        c = self.col
        owner = int(c["owner"][slot])
        return InFlight(
            request_id=self.rid_of[slot],
            entitlement=self.store.name_of[owner],
            priority=float(c["priority"][slot]),
            kv_bytes=float(c["kv_bytes"][slot]),
            charged_tokens=int(c["rec_charged"][slot]),
            admitted_at=float(c["rec_admitted"][slot]),
            resident=bool(c["resident"][slot]),
            spill_from=self.spill_from[slot],
            settled_tokens=float(c["settled"][slot]))

    def clear_record(self, slot: int) -> None:
        """Drop a row's record half; the row dies (and recycles) unless
        an outstanding charge still holds it."""
        c = self.col
        if not c["has_record"][slot]:
            return
        if not c["has_charge"][slot]:
            self.release(slot)
            return
        self.n_records -= 1
        c["has_record"][slot] = False
        c["owner"][slot] = 0
        c["priority"][slot] = 0.0
        c["kv_bytes"][slot] = 0.0
        c["rec_charged"][slot] = 0
        c["rec_admitted"][slot] = 0.0
        c["resident"][slot] = False
        c["settled"][slot] = 0.0
        self.spill_from[slot] = None

    def record_slots_of_owner(self, owner_slot: int) -> np.ndarray:
        """Row slots whose record half belongs to one entitlement, in
        request-id insertion (registration) order."""
        c = self.col
        mask = c["has_record"] & (c["owner"] == owner_slot)
        hits = [s for s in self.slot_of.values() if mask[s]]
        return np.asarray(hits, np.int64)

    # -- charge half ----------------------------------------------------------
    def put_charge(self, charge: Charge) -> int:
        """Write a ledger charge into its row (allocating or completing
        a record-only row)."""
        slot = self.ensure_slot(charge.request_id)
        c = self.col
        c["has_charge"][slot] = True
        c["ch_owner"][slot] = self.store.slot_of[charge.entitlement]
        c["charged"][slot] = charge.charged_tokens
        c["input_tokens"][slot] = charge.input_tokens
        c["max_tokens"][slot] = charge.max_tokens
        c["ch_admitted"][slot] = charge.admitted_at
        return slot

    @hot_path
    def put_charges(self, charges: list, owners: np.ndarray) -> np.ndarray:
        """One admission quantum's accepted charges as batched column
        writes (``owners`` pre-resolved, aligned with ``charges``)."""
        n = len(charges)
        slots = self.ensure_slots([ch.request_id for ch in charges])
        c = self.col
        c["has_charge"][slots] = True
        c["ch_owner"][slots] = owners
        c["charged"][slots] = np.fromiter(
            (ch.charged_tokens for ch in charges), np.float64, count=n)
        c["input_tokens"][slots] = np.fromiter(
            (ch.input_tokens for ch in charges), np.int64, count=n)
        c["max_tokens"][slots] = np.fromiter(
            (ch.max_tokens for ch in charges), np.int64, count=n)
        c["ch_admitted"][slots] = np.fromiter(
            (ch.admitted_at for ch in charges), np.float64, count=n)
        return slots

    @hot_path
    def charge_rows(self, request_ids: list, owners: np.ndarray,
                    charged: np.ndarray, input_tokens: np.ndarray,
                    max_tokens: np.ndarray, admitted_at: float
                    ) -> np.ndarray:
        """Array-native charge insertion (gateway quantum path — no
        per-request ``Charge`` objects).  Returns the row slots."""
        slots = self.ensure_slots(request_ids)
        c = self.col
        c["has_charge"][slots] = True
        c["ch_owner"][slots] = owners
        c["charged"][slots] = charged
        c["input_tokens"][slots] = input_tokens
        c["max_tokens"][slots] = max_tokens
        c["ch_admitted"][slots] = admitted_at
        return slots

    def pop_charge(self, request_id: str) -> Optional[Charge]:
        """Materialize and remove a row's charge half (scalar
        settle/cancel); the row dies unless its record half holds it.
        Returns None when the request has no outstanding charge."""
        slot = self.slot_of.get(request_id)
        if slot is None or not self.col["has_charge"][slot]:
            return None
        ch = self.materialize_charge(slot)
        self.clear_charge(slot)
        return ch

    def materialize_charge(self, slot: int) -> Charge:
        c = self.col
        return Charge(
            request_id=self.rid_of[slot],
            entitlement=self.store.name_of[int(c["ch_owner"][slot])],
            charged_tokens=float(c["charged"][slot]),
            input_tokens=int(c["input_tokens"][slot]),
            max_tokens=int(c["max_tokens"][slot]),
            admitted_at=float(c["ch_admitted"][slot]))

    def clear_charge(self, slot: int) -> None:
        c = self.col
        if not c["has_charge"][slot]:
            return
        if not c["has_record"][slot]:
            self.release(slot)
            return
        c["has_charge"][slot] = False
        c["ch_owner"][slot] = 0
        c["charged"][slot] = 0.0
        c["input_tokens"][slot] = 0
        c["max_tokens"][slot] = 0
        c["ch_admitted"][slot] = 0.0

    def charge_slots_of_owner(self, owner_slot: int) -> list[int]:
        """Row slots whose charge half belongs to one entitlement, in
        request-id insertion order (matches the legacy dict sweep)."""
        c = self.col
        mask = c["has_charge"] & (c["ch_owner"] == owner_slot)
        return [s for s in self.slot_of.values() if mask[s]]


class InFlightRow:
    """``InFlight``-compatible VIEW over one request-table row.

    Same attribute surface as the dataclass, but every read and write
    goes straight to the columns — ``pool.in_flight[rid]`` returns
    these (dicts are views, arrays are truth)."""

    __slots__ = ("_table", "_slot")

    def __init__(self, table: RequestTable, slot: int) -> None:
        self._table = table
        self._slot = slot

    @property
    def slot(self) -> int:
        return self._slot

    @property
    def request_id(self) -> str:
        return self._table.rid_of[self._slot]

    @property
    def entitlement(self) -> str:
        t = self._table
        return t.store.name_of[int(t.col["owner"][self._slot])]

    @property
    def priority(self) -> float:
        return float(self._table.col["priority"][self._slot])

    @priority.setter
    def priority(self, v: float) -> None:
        self._table.col["priority"][self._slot] = v

    @property
    def kv_bytes(self) -> float:
        return float(self._table.col["kv_bytes"][self._slot])

    @kv_bytes.setter
    def kv_bytes(self, v: float) -> None:
        self._table.col["kv_bytes"][self._slot] = v

    @property
    def charged_tokens(self) -> int:
        return int(self._table.col["rec_charged"][self._slot])

    @charged_tokens.setter
    def charged_tokens(self, v: int) -> None:
        self._table.col["rec_charged"][self._slot] = v

    @property
    def admitted_at(self) -> float:
        return float(self._table.col["rec_admitted"][self._slot])

    @admitted_at.setter
    def admitted_at(self, v: float) -> None:
        self._table.col["rec_admitted"][self._slot] = v

    @property
    def resident(self) -> bool:
        return bool(self._table.col["resident"][self._slot])

    @resident.setter
    def resident(self, v: bool) -> None:
        self._table.col["resident"][self._slot] = v

    @property
    def spill_from(self) -> Optional[tuple]:
        return self._table.spill_from[self._slot]

    @spill_from.setter
    def spill_from(self, v: Optional[tuple]) -> None:
        self._table.spill_from[self._slot] = v

    @property
    def settled_tokens(self) -> float:
        return float(self._table.col["settled"][self._slot])

    @settled_tokens.setter
    def settled_tokens(self, v: float) -> None:
        self._table.col["settled"][self._slot] = v

    def materialize(self) -> InFlight:
        return self._table.materialize_record(self._slot)

    def __repr__(self) -> str:
        return (f"InFlightRow(slot={self._slot}, "
                f"request_id={self.request_id!r}, "
                f"entitlement={self.entitlement!r}, "
                f"charged_tokens={self.charged_tokens}, "
                f"resident={self.resident})")


class InFlightMap:
    """Dict facade over a pool's request-table RECORD rows — the
    ``pool.in_flight`` surface.  Membership, iteration and length count
    admission records only (a charge-only row is ledger state, not an
    in-flight request).  ``__setitem__`` writes an ``InFlight``-shaped
    object into its row (the migration attach path)."""

    __slots__ = ("_table",)

    def __init__(self, table: RequestTable) -> None:
        self._table = table

    def __len__(self) -> int:
        return self._table.n_records

    def __bool__(self) -> bool:
        return self._table.n_records > 0

    def __contains__(self, request_id: str) -> bool:
        t = self._table
        slot = t.slot_of.get(request_id)
        return slot is not None and bool(t.col["has_record"][slot])

    def __iter__(self) -> Iterator[str]:
        t = self._table
        has = t.col["has_record"]
        return (rid for rid, slot in t.slot_of.items() if has[slot])

    def keys(self) -> list[str]:
        return list(self)

    def __getitem__(self, request_id: str) -> InFlightRow:
        t = self._table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot]:
            raise KeyError(request_id)
        return InFlightRow(t, slot)

    def get(self, request_id: str, default=None):
        t = self._table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot]:
            return default
        return InFlightRow(t, slot)

    def __setitem__(self, request_id: str, rec) -> None:
        if rec.request_id != request_id:
            raise ValueError(f"record id {rec.request_id!r} != key "
                             f"{request_id!r}")
        self._table.put_record(rec)

    def __delitem__(self, request_id: str) -> None:
        t = self._table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot]:
            raise KeyError(request_id)
        t.clear_record(slot)

    def pop(self, request_id: str, default=None):
        t = self._table
        slot = t.slot_of.get(request_id)
        if slot is None or not t.col["has_record"][slot]:
            return default
        rec = t.materialize_record(slot)
        t.clear_record(slot)
        return rec

    def values(self) -> Iterator[InFlightRow]:
        t = self._table
        has = t.col["has_record"]
        return (InFlightRow(t, slot) for slot in t.slot_of.values()
                if has[slot])

    def items(self) -> Iterator[tuple[str, InFlightRow]]:
        t = self._table
        has = t.col["has_record"]
        return ((rid, InFlightRow(t, slot))
                for rid, slot in t.slot_of.items() if has[slot])

    def __repr__(self) -> str:
        return f"InFlightMap(n_records={self._table.n_records})"
