"""Fleet capacity planner — entitlement-driven autoscaling + cross-pool
rebalancing on the vectorized control plane.

The paper's central claim is that token pools authorize *both*
admission and autoscaling from one capacity model.  This module is the
autoscaling half at FLEET scale: :func:`plan_fleet` consumes the
per-pool signals the batched accounting tick already produces (demand
EWMA, reserved baselines, replica bounds) and emits, in ONE fused
jit/vmapped dispatch for the whole fleet, a :class:`ScaleDecision` per
pool — the reserved-floor + headroom-on-demand policy with scale-down
hysteresis of the scalar ``core.autoscaler`` (which survives as the
single-pool PARITY ORACLE; ``tests/test_fleet.py`` pins the two
decision-identical).

On top of the scale decisions, :class:`FleetPlanner` proposes
cross-pool REBALANCES: an ELASTIC/SPOT entitlement that stays
underserved on a scarce pool (debt above threshold, or allocation
persistently below its demand) for ``starve_persistence_ticks``
consecutive plans is migrated to the slack pool with the most headroom
(capacity-aware pool selection in the spirit of token-budget-aware
pool routing; debt-based fairness per VTC).

Migration invariants (``TokenPool.detach_entitlement`` /
``attach_entitlement``, applied by ``PoolManager.migrate_entitlement``):

  * the ledger bucket moves with its ACCRUED LEVEL and outstanding
    charges — no budget is minted or burned by a move (the burst
    window re-bases to the target ledger, clamping if smaller);
  * ``EntitlementStatus`` moves verbatim — debt, burst and usage
    counters carry, so an underserved tenant arrives at the target
    with the compensatory priority it is owed (cross-pool debt);
  * in-flight records move — completions settle on the NEW owner,
    which also holds their charges;
  * the demand EWMA moves — the target's next tick sees the real
    demand instead of a cold start;
  * the source lease is released before the target lease is
    submitted; the target's authorized ceiling is raised first
    (``PoolManager.migrate_entitlement``) so a planner-shrunk target
    does not spuriously degrade the arrival.

The closed control loop this enables (wired through
``PoolManager.plan_quantum``):

  admission → batched tick → plan_fleet → authorize/provision →
  admission

— the same signals that deny spot traffic also raise capacity, which
is the paper's consistency story (``benchmarks/experiment3_autoscale``).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import control_plane
from repro.core.autoscaler import ScaleDecision, replicas_for
from repro.core.markers import kernel
from repro.core.pool import TickRecord, TokenPool
from repro.core.types import Resources, ServiceClass

#: Reason codes emitted by :func:`plan_fleet` (index = code), matching
#: the scalar ``Autoscaler.plan`` reason strings.
REASONS = ("steady", "scale_up:reserved", "scale_up:demand",
           "hold:cooldown", "scale_down")
_STEADY, _UP_RESERVED, _UP_DEMAND, _HOLD, _DOWN = range(5)


@dataclasses.dataclass(frozen=True)
class FleetPlannerConfig:
    """Scale policy (identical semantics to ``AutoscalerConfig``) plus
    the rebalance policy knobs.  Frozen → usable as a static jit arg."""

    headroom: float = 1.2          # demand multiplier before scaling
    demand_ewma: float = 0.5       # smoothing of the demand signal
    cooldown_ticks: int = 5        # consecutive low ticks before shrink
    #: elastic entitlements migrate once their debt EWMA crosses this
    debt_migrate_threshold: float = 0.25
    #: spot entitlements count as starved when alloc < frac · demand
    starve_frac: float = 0.5
    #: consecutive starved plans before a migration is proposed
    starve_persistence_ticks: int = 3
    #: plans an entitlement is pinned to its pool after migrating
    migrate_cooldown_ticks: int = 10
    #: migrations proposed per scarce pool per plan (anti-thrash)
    max_migrations_per_pool: int = 1


@dataclasses.dataclass(frozen=True)
class RebalanceProposal:
    """Move ``entitlement`` from the scarce ``src`` to the slack
    ``dst``, carrying ``debt`` (the Eq. 2 EWMA at proposal time)."""

    entitlement: str
    src: str
    dst: str
    debt: float
    baseline_tps: float
    reason: str                     # "debt" | "starved_demand"


@dataclasses.dataclass
class FleetPlan:
    """One planning round: per-pool decisions + rebalance proposals.
    ``applied``/``preempted`` are filled by ``PoolManager.plan_quantum``
    when the plan is executed."""

    decisions: dict[str, ScaleDecision]
    migrations: list[RebalanceProposal]
    #: replicas the fleet cannot place (need beyond maxReplicas), tok/s
    #: equivalent — scarcity observability, keyed by pool
    unmet_replicas: dict[str, float]
    applied: list[RebalanceProposal] = dataclasses.field(
        default_factory=list)
    #: proposals NOT applied because the destination pool lost its
    #: replicas between planning and execution (same-quantum failure) —
    #: the entitlement stays put rather than migrating into a dead pool
    skipped: list[RebalanceProposal] = dataclasses.field(
        default_factory=list)
    preempted: dict[str, list[str]] = dataclasses.field(
        default_factory=dict)
    #: pools whose AUTHORIZED replica count moved this round, as
    #: (old, new) — one entry per actual scaling event, unlike the
    #: per-round decisions which repeat desired > current every tick
    #: while provisioning lag is converging
    scale_events: dict[str, tuple[int, int]] = dataclasses.field(
        default_factory=dict)


def _plan_one(current, lo, hi, per_tps, per_kv, per_conc,
              res_tps, res_kv, res_conc, demand, ewma_prev, seeded,
              low_ticks, config: FleetPlannerConfig):
    """Scale policy for ONE pool — the jnp mirror of the scalar
    ``Autoscaler.observe_demand`` + ``Autoscaler.plan`` pair.
    ``plan_fleet`` vmaps this over the pool axis."""
    g = config.demand_ewma
    ewma = jnp.where(seeded, g * ewma_prev + (1.0 - g) * demand, demand)

    def dim(need, per):
        return jnp.where(per > 0.0, need / jnp.maximum(per, 1e-30),
                         jnp.where(need > 0.0, jnp.inf, 0.0))

    need_reserved = jnp.maximum(
        dim(res_tps, per_tps),
        jnp.maximum(dim(res_kv, per_kv), dim(res_conc, per_conc)))
    need_demand = dim(ewma * config.headroom, per_tps)
    need = jnp.maximum(need_reserved, need_demand)
    # an unsatisfiable dimension (need inf) must clamp UP to hi, not
    # wrap through the int cast — bound the ceil operand first
    desired = jnp.maximum(
        1, jnp.ceil(jnp.minimum(need, 1e9)).astype(jnp.int32))
    desired = jnp.clip(desired, lo, hi)

    scale_up = desired > current
    scale_dn = desired < current
    hold = scale_dn & (low_ticks + 1 < config.cooldown_ticks)
    new_low = jnp.where(hold, low_ticks + 1, 0)
    desired = jnp.where(hold, current, desired)
    reason = jnp.where(
        scale_up,
        jnp.where(need_demand > need_reserved, _UP_DEMAND, _UP_RESERVED),
        jnp.where(hold, _HOLD, jnp.where(scale_dn, _DOWN, _STEADY)))
    return desired, reason.astype(jnp.int32), ewma, new_low, need


@kernel(oracle="repro.core.autoscaler.Autoscaler.plan")
@partial(jax.jit, static_argnames=("config",))
def plan_fleet(current: jax.Array, lo: jax.Array, hi: jax.Array,
               per_tps: jax.Array, per_kv: jax.Array, per_conc: jax.Array,
               res_tps: jax.Array, res_kv: jax.Array, res_conc: jax.Array,
               demand_tps: jax.Array, ewma_prev: jax.Array,
               seeded: jax.Array, low_ticks: jax.Array,
               config: FleetPlannerConfig = FleetPlannerConfig(),
               ) -> tuple[jax.Array, jax.Array, jax.Array, jax.Array,
                          jax.Array]:
    """One fused scale plan for the WHOLE fleet.

    Every argument carries a leading pool axis ([P]); the returns are
    ``(desired, reason_code, demand_ewma, low_ticks, need_replicas)``,
    all [P].  ``need_replicas`` is the unclamped fractional requirement
    — ``need > hi`` means the pool is SCARCE (feeds the rebalancer),
    ``need < hi`` leaves slack.  Padding rows (see
    ``FleetPlanner._arrays``) use per_replica 1 / bounds [1, 1] so they
    stay inert and finite."""

    def one(c, l, h, pt, pk, pc, rt, rk, rc, d, e, s, lt):
        return _plan_one(c, l, h, pt, pk, pc, rt, rk, rc, d, e, s, lt,
                         config)

    return jax.vmap(one)(current, lo, hi, per_tps, per_kv, per_conc,
                         res_tps, res_kv, res_conc, demand_tps,
                         ewma_prev, seeded, low_ticks)


def _reserve_replicas(espec, pool: TokenPool) -> float:
    """Replica cost of hosting an entitlement's reserve on ``pool`` —
    the same rule the virtual-node lease uses: spot/preemptible
    reserve nothing."""
    if espec.qos.service_class in (ServiceClass.SPOT,
                                   ServiceClass.PREEMPTIBLE):
        return 0.0
    return max(0.0, replicas_for(espec.baseline, pool.spec.per_replica))


@dataclasses.dataclass
class _PoolPlanState:
    """Planner-side hysteresis state for one pool."""

    ewma: float = 0.0
    seeded: bool = False
    low_ticks: int = 0


class FleetPlanner:
    """Stateful shell around :func:`plan_fleet` + the rebalancer.

    Holds the per-pool demand EWMA / cooldown state and the
    per-entitlement starvation counters between plans; each
    :meth:`plan` call gathers the fleet's signals, runs ONE fused
    kernel dispatch (padded to a power-of-two pool bucket so fleet
    membership churn does not retrace it), and derives rebalance
    proposals from the scarcity outputs."""

    def __init__(self, config: Optional[FleetPlannerConfig] = None
                 ) -> None:
        self.config = (config if config is not None
                       else FleetPlannerConfig())
        self._state: dict[str, _PoolPlanState] = {}
        self._starved: dict[str, int] = {}          # entitlement → plans
        self._cooldown: dict[str, int] = {}         # entitlement → plans
        self._plans = 0

    # -- signal gathering ------------------------------------------------------
    @staticmethod
    def pool_demand(pool: TokenPool,
                    record: Optional[TickRecord]) -> float:
        """Total demand (tok/s) — the sum of the demand EWMAs the tick
        emits (admitted + denied demand, so denial pressure raises
        capacity).  Without a tick record this is one masked column sum
        over the pool's resident arrays (``demand_total_tps``), not a
        per-name dict walk."""
        if record is None:
            return pool.demand_total_tps()
        return float(sum(record.demand_tps.values()))

    def _arrays(self, pools: dict[str, TokenPool],
                records: dict[str, TickRecord]) -> tuple[list, dict]:
        names = sorted(pools)
        width = control_plane.bucket_width(len(names))
        f32 = lambda fill: np.full(width, fill, np.float32)   # noqa: E731
        i32 = lambda fill: np.full(width, fill, np.int32)     # noqa: E731
        arr = {
            "current": i32(1), "lo": i32(1), "hi": i32(1),
            "per_tps": f32(1.0), "per_kv": f32(1.0), "per_conc": f32(1.0),
            "res_tps": f32(0.0), "res_kv": f32(0.0), "res_conc": f32(0.0),
            "demand_tps": f32(0.0), "ewma_prev": f32(0.0),
            "seeded": np.zeros(width, bool), "low_ticks": i32(0),
        }
        for i, name in enumerate(names):
            pool = pools[name]
            st = self._state.setdefault(name, _PoolPlanState())
            reserved = pool.reserved_baseline()
            per = pool.spec.per_replica
            arr["current"][i] = pool.replicas
            arr["lo"][i] = pool.spec.scaling.min_replicas
            arr["hi"][i] = pool.spec.scaling.max_replicas
            arr["per_tps"][i] = per.tokens_per_second
            arr["per_kv"][i] = per.kv_bytes
            arr["per_conc"][i] = per.concurrency
            arr["res_tps"][i] = reserved.tokens_per_second
            arr["res_kv"][i] = reserved.kv_bytes
            arr["res_conc"][i] = reserved.concurrency
            arr["demand_tps"][i] = self.pool_demand(
                pool, records.get(name))
            arr["ewma_prev"][i] = st.ewma
            arr["seeded"][i] = st.seeded
            arr["low_ticks"][i] = st.low_ticks
        return names, arr

    # -- the plan --------------------------------------------------------------
    def plan(self, pools: dict[str, TokenPool],
             records: Optional[dict[str, TickRecord]] = None,
             now: float = 0.0) -> FleetPlan:
        """One planning round over the fleet: ONE ``plan_fleet``
        dispatch + the Python-side rebalance pass."""
        records = records or {}
        self._plans += 1
        # drop state of pools that left the fleet
        for gone in set(self._state) - set(pools):
            del self._state[gone]
        if not pools:
            return FleetPlan(decisions={}, migrations=[],
                             unmet_replicas={})
        names, arr = self._arrays(pools, records)
        desired, reason, ewma, low, need = plan_fleet(
            **{k: jnp.asarray(v) for k, v in arr.items()},
            config=self.config)
        desired = np.asarray(desired)
        reason = np.asarray(reason)
        ewma = np.asarray(ewma)
        low = np.asarray(low)
        need = np.asarray(need)

        decisions: dict[str, ScaleDecision] = {}
        unmet: dict[str, float] = {}
        for i, name in enumerate(names):
            st = self._state[name]
            st.ewma = float(ewma[i])
            st.seeded = True
            st.low_ticks = int(low[i])
            decisions[name] = ScaleDecision(
                current=int(arr["current"][i]), desired=int(desired[i]),
                reserved_tps=float(arr["res_tps"][i]),
                demand_tps=float(ewma[i]),
                reason=REASONS[int(reason[i])], pool=name)
            over = float(need[i]) - float(arr["hi"][i])
            if over > 1e-6:
                unmet[name] = over
        migrations = self._rebalance(pools, records, names, need, arr)
        return FleetPlan(decisions=decisions, migrations=migrations,
                         unmet_replicas=unmet)

    # -- rebalancing -----------------------------------------------------------
    def _starvation(self, pool: TokenPool, name: str,
                    record: Optional[TickRecord]) -> Optional[str]:
        """Starvation signal for one elastic/spot entitlement, or None."""
        st = pool.status[name]
        klass = pool.entitlements[name].qos.service_class
        if klass is ServiceClass.ELASTIC \
                and st.debt >= self.config.debt_migrate_threshold:
            return "debt"
        if record is None:
            return None
        demand = record.demand_tps.get(name, 0.0)
        alloc = record.allocations.get(name, 0.0)
        if demand > 1e-9 and alloc < self.config.starve_frac * demand:
            return "starved_demand"
        return None

    def _rebalance(self, pools: dict[str, TokenPool],
                   records: dict[str, TickRecord], names: list[str],
                   need: np.ndarray, arr: dict) -> list[RebalanceProposal]:
        cfg = self.config
        hi = {n: float(arr["hi"][i]) for i, n in enumerate(names)}
        need_by = {n: float(need[i]) for i, n in enumerate(names)}
        slack = {n: hi[n] - need_by[n] for n in names}

        # 1. persistence counters for every migratable entitlement
        live: set[str] = set()
        for pname in names:
            pool = pools[pname]
            rec = records.get(pname)
            for ent, espec in pool.entitlements.items():
                if espec.qos.service_class not in (ServiceClass.ELASTIC,
                                                   ServiceClass.SPOT):
                    continue
                live.add(ent)
                if self._starvation(pool, ent, rec) is not None:
                    self._starved[ent] = self._starved.get(ent, 0) + 1
                else:
                    self._starved.pop(ent, None)
        for gone in set(self._starved) - live:
            del self._starved[gone]

        # 2. proposals: scarce pools shed their most-indebted starved
        #    entitlements onto the slackest pool that can hold them
        proposals: list[RebalanceProposal] = []
        for src in names:
            if need_by[src] <= hi[src] + 1e-6:
                continue                             # not scarce
            pool = pools[src]
            rec = records.get(src)
            candidates = []
            for ent, espec in pool.entitlements.items():
                if espec.qos.service_class not in (ServiceClass.ELASTIC,
                                                   ServiceClass.SPOT):
                    continue
                if self._starved.get(ent, 0) < cfg.starve_persistence_ticks:
                    continue
                if self._plans - self._cooldown.get(ent, -10**9) \
                        < cfg.migrate_cooldown_ticks:
                    continue
                why = self._starvation(pool, ent, rec)
                if why is None:
                    continue
                candidates.append((pool.status[ent].debt,
                                   ent, espec, why))
            candidates.sort(key=lambda c: (-c[0], c[1]))
            moved = 0
            for debt, ent, espec, why in candidates:
                if moved >= cfg.max_migrations_per_pool:
                    break
                dst = self._pick_target(pools, names, src, espec, slack)
                if dst is None:
                    continue
                slack[dst] -= _reserve_replicas(espec,
                                                pools[dst])
                self._cooldown[ent] = self._plans
                self._starved.pop(ent, None)
                proposals.append(RebalanceProposal(
                    entitlement=ent, src=src, dst=dst, debt=float(debt),
                    baseline_tps=espec.baseline.tokens_per_second,
                    reason=why))
                moved += 1
        return proposals

    def _pick_target(self, pools: dict[str, TokenPool], names: list[str],
                     src: str, espec, slack: dict[str, float]
                     ) -> Optional[str]:
        """Slackest pool (≠ src) whose remaining headroom under
        maxReplicas can absorb the entitlement's baseline reserve."""
        best, best_slack = None, 0.0
        for dst in names:
            if dst == src:
                continue
            remaining = slack[dst] - _reserve_replicas(espec, pools[dst])
            if remaining < -1e-6:
                continue
            if best is None or slack[dst] > best_slack:
                best, best_slack = dst, slack[dst]
        return best
