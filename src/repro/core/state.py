"""StateStore — the control plane's low-latency state backend.

The paper keeps per-entitlement state in Redis (§4.3): in-flight count,
burst intensity b_e, accumulated debt d_e, effective allocation, updated
on every request completion via the gateway callback.  This module
provides an in-memory store with the same operation set (get / set /
compare-and-set / atomic increment / TTL expiry) so the control plane is
written against the Redis contract and a real Redis client can be
swapped in behind the same interface.

Deterministic: expiry is evaluated against an explicit ``now``.
"""
from __future__ import annotations

import copy
import dataclasses
from typing import Any, Callable, Optional


@dataclasses.dataclass
class _Entry:
    value: Any
    version: int = 0
    expires_at: Optional[float] = None


class CASConflict(RuntimeError):
    """Optimistic-concurrency conflict (another writer won)."""


class StateStore:
    """In-memory key/value store with versions, CAS, counters and TTL.

    Mirrors the subset of Redis used by the auth service: plain
    GET/SET, WATCH/MULTI-style compare-and-set, INCRBY, EXPIRE.
    """

    def __init__(self) -> None:
        self._data: dict[str, _Entry] = {}

    # -- plain KV ---------------------------------------------------------
    def get(self, key: str, now: float = 0.0) -> Any:
        e = self._data.get(key)
        if e is None:
            return None
        if e.expires_at is not None and now >= e.expires_at:
            del self._data[key]
            return None
        return e.value

    def set(self, key: str, value: Any, now: float = 0.0,
            ttl_s: Optional[float] = None) -> int:
        prev = self._data.get(key)
        version = (prev.version + 1) if prev is not None else 1
        expires_at = (now + ttl_s) if ttl_s is not None else None
        self._data[key] = _Entry(value=value, version=version,
                                 expires_at=expires_at)
        return version

    def get_versioned(self, key: str, now: float = 0.0) -> tuple[Any, int]:
        e = self._data.get(key)
        if e is None:
            return None, 0
        if e.expires_at is not None and now >= e.expires_at:
            del self._data[key]
            return None, 0
        return e.value, e.version

    # -- optimistic concurrency -------------------------------------------
    def compare_and_set(self, key: str, value: Any, expected_version: int,
                        now: float = 0.0) -> int:
        _, version = self.get_versioned(key, now)
        if version != expected_version:
            raise CASConflict(
                f"{key}: expected v{expected_version}, found v{version}")
        return self.set(key, value, now)

    def update(self, key: str, fn: Callable[[Any], Any], now: float = 0.0,
               max_retries: int = 8) -> Any:
        """Read-modify-write with CAS retry (Redis WATCH/MULTI loop)."""
        for _ in range(max_retries):
            value, version = self.get_versioned(key, now)
            new_value = fn(copy.deepcopy(value))
            try:
                if version == 0:
                    self.set(key, new_value, now)
                else:
                    self.compare_and_set(key, new_value, version, now)
                return new_value
            except CASConflict:  # pragma: no cover - single-threaded here
                continue
        raise CASConflict(f"update({key}) exhausted retries")

    # -- counters -----------------------------------------------------------
    def incr(self, key: str, by: float = 1.0, now: float = 0.0) -> float:
        """Atomic increment with Redis INCRBY semantics: the key's TTL
        is PRESERVED (``set`` would rewrite the entry and clear
        ``expires_at``); an absent or expired key starts from 0 with no
        expiry."""
        e = self._data.get(key)
        if e is not None and e.expires_at is not None \
                and now >= e.expires_at:
            del self._data[key]
            e = None
        if e is None:
            new = by + 0.0
            self._data[key] = _Entry(value=new, version=1)
        else:
            new = (e.value or 0.0) + by
            e.value = new
            e.version += 1
        return new

    def incr_many(self, deltas: dict, now: float = 0.0) -> None:
        """Batched increments — the Redis MULTI/pipeline analogue the
        hot paths use so a quantum issues ONE store call instead of one
        ``incr`` per distinct key."""
        for key, by in deltas.items():
            self.incr(key, by, now)

    # -- TTL -----------------------------------------------------------------
    def expire(self, key: str, ttl_s: float, now: float = 0.0) -> bool:
        e = self._data.get(key)
        if e is None:
            return False
        e.expires_at = now + ttl_s
        return True

    def keys(self, prefix: str = "", now: float = 0.0) -> list[str]:
        out = []
        for k in list(self._data):
            if k.startswith(prefix) and self.get(k, now) is not None:
                out.append(k)
        return sorted(out)

    def delete(self, key: str) -> bool:
        return self._data.pop(key, None) is not None
