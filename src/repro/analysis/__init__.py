"""Control-plane sanitizer — AST invariant passes for the repo's three
docstring-enforced contracts (mirror invalidation, dtype discipline,
retrace bucketing) plus hot-path loop hygiene and kernel↔oracle parity
coverage.  stdlib ``ast`` only; run as ``python -m repro.analysis
--strict src/`` (blocking in CI).

Rules:

* ``mirror-invalidation`` — host writes to device-mirrored store
  columns must ``mark_dirty()``;
* ``dtype-discipline`` — no f64 into jit kernel args, no f32
  truncation of f64 accumulator columns;
* ``retrace-hazard`` — kernel calls shape-bucketed, static args
  literal+hashable, no mutable host capture;
* ``hot-path-scalar-loop`` — ``@hot_path`` functions never loop over
  store/table rows in Python;
* ``oracle-parity`` — every control-plane jit kernel registers a
  scalar oracle (``@kernel``) with a test referencing both.

Waive a finding in place with ``# repro: allow[<rule>] -- <reason>``.
"""
from repro.analysis.core import (  # noqa: F401
    Finding,
    PASS_REGISTRY,
    Pass,
    Project,
    Report,
    SourceFile,
    analyze,
    register_pass,
)
from repro.analysis.manifest import Manifest, default_manifest  # noqa: F401

__all__ = [
    "Finding", "Manifest", "PASS_REGISTRY", "Pass", "Project", "Report",
    "SourceFile", "analyze", "default_manifest", "register_pass",
]
