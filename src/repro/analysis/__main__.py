"""CLI: ``python -m repro.analysis [--strict] [paths...]``.

Exit status: 0 when clean (no unwaived findings; under ``--strict``
additionally every waiver carries a reason), 1 otherwise.  ``--report``
writes the rule → count → waived summary JSON (the CI artifact
``ANALYSIS_report.json``).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

from repro.analysis.core import PASS_REGISTRY, analyze


def main(argv: list[str] | None = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Control-plane static analysis (AST invariant passes).")
    parser.add_argument("paths", nargs="*", default=["src"],
                        help="files/directories to scan (default: src)")
    parser.add_argument("--strict", action="store_true",
                        help="also fail on waivers without a -- reason")
    parser.add_argument("--rules", default=None,
                        help="comma-separated rule subset (default: all)")
    parser.add_argument("--tests-dir", default="tests",
                        help="test tree for oracle-parity cross-refs")
    parser.add_argument("--report", default=None, metavar="PATH",
                        help="write summary JSON (rule → count → waived)")
    parser.add_argument("--json", action="store_true", dest="as_json",
                        help="print the summary JSON to stdout instead of "
                             "human-readable findings")
    parser.add_argument("--list-rules", action="store_true",
                        help="list registered rules and exit")
    args = parser.parse_args(argv)

    if args.list_rules:
        from repro.analysis import passes  # noqa: F401
        for rule in sorted(PASS_REGISTRY):
            print(f"{rule}: {PASS_REGISTRY[rule].description}")
        return 0

    rules = ([r.strip() for r in args.rules.split(",") if r.strip()]
             if args.rules else None)
    try:
        report = analyze(args.paths, tests_dir=args.tests_dir, rules=rules)
    except (ValueError, OSError, SyntaxError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2

    if args.report:
        out = Path(args.report)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(report.to_json(), indent=2) + "\n")

    if args.as_json:
        print(json.dumps(report.to_json(), indent=2))
    else:
        for f in report.findings:
            print(f.format())
        for path, line, rs in report.reasonless_waivers:
            sev = "error" if args.strict else "warning"
            print(f"{path}:{line}: {sev}: waiver for {', '.join(rs)} "
                  f"has no '-- <reason>'")
        n_waived = len(report.waived)
        print(f"{report.files_scanned} files, "
              f"{len(report.rules_run)} rules: "
              f"{len(report.unwaived)} unwaived finding(s), "
              f"{n_waived} waived")
    return 0 if report.ok(strict=args.strict) else 1


if __name__ == "__main__":
    sys.exit(main())
