"""Column manifest — the machine-readable contract the passes run on.

``repro.core.resident`` and ``repro.core.request_table`` each export a
``column_manifest()`` dict (columns → dtype, the device-mirrored set,
the f32 kernel-facing set, sanctioned mutators).  This module merges
them into one :class:`Manifest` and round-trips it through JSON so the
analyzer's view of the contract can be pinned/diffed in CI artifacts.
"""
from __future__ import annotations

import dataclasses
import json


@dataclasses.dataclass(frozen=True)
class Manifest:
    """Merged column contract across every exporting store."""

    stores: tuple[dict, ...]

    @property
    def mirrored(self) -> set[str]:
        """Columns backed by a cached device mirror — host writes must
        invalidate (`mirror-invalidation` pass)."""
        return {c for s in self.stores for c in s.get("mirrored", ())}

    @property
    def kernel_f32(self) -> set[str]:
        return {c for s in self.stores for c in s.get("kernel_f32", ())}

    @property
    def f64_columns(self) -> set[str]:
        """Accumulator columns (float64 contract — `dtype-discipline`)."""
        return {name
                for s in self.stores
                for name, dt in s.get("columns", {}).items()
                if dt == "float64"}

    @property
    def sanctioned_mutators(self) -> set[str]:
        return {q for s in self.stores
                for q in s.get("sanctioned_mutators", ())}

    def to_json(self) -> str:
        return json.dumps({"stores": list(self.stores)}, indent=2,
                          sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "Manifest":
        return cls(stores=tuple(json.loads(text)["stores"]))

    @classmethod
    def from_exports(cls, exports: list[dict]) -> "Manifest":
        return cls(stores=tuple(exports))


def default_manifest() -> Manifest:
    """The live contract, imported from the stores themselves so a new
    column is covered the moment it is declared."""
    from repro.core import request_table, resident
    from repro.telemetry import flight

    return Manifest.from_exports(
        [resident.column_manifest(), request_table.column_manifest(),
         flight.column_manifest()])
