"""Analyzer framework: findings, waivers, project model, pass registry.

Everything is stdlib ``ast`` — the analyzer never imports the code it
scans (the column manifest is the one exception, loaded from
``repro.core.resident`` / ``repro.core.request_table`` by
``repro.analysis.manifest``; fixture tests inject their own).

Waiver syntax (line-scoped, applies to its own line, or — when written
on a comment-only line — to the next code line)::

    self.store.col["burst"][slot] = v   # repro: allow[mirror-invalidation] -- adopted below

File-scoped (anywhere in the file, typically the header)::

    # repro: allow-file[retrace-hazard] -- generated shim, no jit calls survive

A waiver without a ``-- reason`` is itself an error under ``--strict``.
"""
from __future__ import annotations

import ast
import dataclasses
import re
from pathlib import Path
from typing import Iterable, Optional

WAIVER_RE = re.compile(
    r"#\s*repro:\s*allow(?P<scope>-file)?\["
    r"(?P<rules>[A-Za-z0-9_,\s-]+)\]"
    r"(?:\s*--\s*(?P<reason>\S.*?))?\s*$")

#: calls that invalidate (or wholesale replace) the device mirror
INVALIDATORS = ("mark_dirty", "mark_dirty_slot", "adopt_device",
                "_membership_changed", "_membership_changed_shard")

#: ``np.<ufunc>.at`` in-place scatter ops treated as column writes
_UFUNC_AT = ("add", "subtract", "maximum", "minimum", "multiply")


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str
    path: str
    line: int
    message: str
    waived: bool = False
    waive_reason: Optional[str] = None

    def format(self) -> str:
        tail = f"  [waived: {self.waive_reason}]" if self.waived else ""
        return f"{self.path}:{self.line}: {self.rule}: {self.message}{tail}"


@dataclasses.dataclass
class Waiver:
    rules: tuple[str, ...]
    line: int                 # code line the waiver applies to (0 = file)
    reason: Optional[str]
    file_scoped: bool = False


class SourceFile:
    """One parsed source file: AST + waiver table."""

    def __init__(self, path: str, text: str) -> None:
        self.path = path
        self.text = text
        self.tree = ast.parse(text, filename=path)
        self.waivers: list[Waiver] = []
        self._parse_waivers(text.splitlines())

    def _parse_waivers(self, lines: list[str]) -> None:
        pending: list[Waiver] = []   # comment-line waivers awaiting code
        for i, raw in enumerate(lines, start=1):
            m = WAIVER_RE.search(raw)
            code = raw.split("#", 1)[0].strip()
            if m:
                rules = tuple(r.strip() for r in m.group("rules").split(",")
                              if r.strip())
                w = Waiver(rules=rules, line=i, reason=m.group("reason"),
                           file_scoped=m.group("scope") is not None)
                if w.file_scoped:
                    w = dataclasses.replace(w, line=0)
                    self.waivers.append(w)
                elif code:               # waiver on a code line
                    self.waivers.append(w)
                else:                    # comment-only: bind to next code line
                    pending.append(w)
            elif code and pending:
                for w in pending:
                    self.waivers.append(dataclasses.replace(w, line=i))
                pending = []
        self.waivers.extend(pending)     # trailing orphans keep comment line

    def waiver_for(self, rule: str, line: int) -> Optional[Waiver]:
        for w in self.waivers:
            if rule in w.rules and (w.file_scoped or w.line == line):
                return w
        return None


@dataclasses.dataclass(frozen=True)
class KernelDecl:
    """A ``@kernel(oracle=...)`` declaration found in the AST."""

    name: str
    oracle: Optional[str]     # None → malformed declaration
    path: str
    line: int


@dataclasses.dataclass(frozen=True)
class FuncDecl:
    """A function of interest (hot path / jit kernel) with its context."""

    qualname: str             # "Class.method" or "func"
    node: ast.AST
    file: "SourceFile"


class Project:
    """Everything the passes share: parsed files, the column manifest,
    kernel/hot-path declarations, jit decorations, mutable globals."""

    def __init__(self, files: list[SourceFile], manifest,
                 tests: Optional[dict[str, set[str]]] = None) -> None:
        self.files = files
        self.manifest = manifest
        #: test file path → set of identifiers referenced in it
        self.tests = tests or {}
        self.kernels: dict[str, KernelDecl] = {}
        self.hot_paths: list[FuncDecl] = []
        self.jit_defs: list[FuncDecl] = []
        #: module-level dict/list/set literal names that some scanned
        #: code mutates (subscript-store / aug-assign / del)
        self.mutable_globals: set[str] = set()
        self._collect()

    def _collect(self) -> None:
        literal_globals: set[str] = set()
        mutated: set[str] = set()
        for f in self.files:
            for node in f.tree.body:
                if isinstance(node, (ast.Assign, ast.AnnAssign)) and isinstance(
                        node.value, (ast.Dict, ast.List, ast.Set, ast.DictComp,
                                     ast.ListComp, ast.SetComp)):
                    tgts = (node.targets if isinstance(node, ast.Assign)
                            else [node.target])
                    for t in tgts:
                        if isinstance(t, ast.Name):
                            literal_globals.add(t.id)
            for node, qualname in iter_functions(f.tree):
                decs = node.decorator_list
                if any(_dec_is(d, "hot_path") for d in decs):
                    self.hot_paths.append(FuncDecl(qualname, node, f))
                if any(_dec_mentions(d, "jit") for d in decs):
                    self.jit_defs.append(FuncDecl(qualname, node, f))
                for d in decs:
                    if isinstance(d, ast.Call) and _dec_is(d.func, "kernel"):
                        oracle = None
                        for kw in d.keywords:
                            if kw.arg == "oracle" and isinstance(
                                    kw.value, ast.Constant) and isinstance(
                                    kw.value.value, str):
                                oracle = kw.value.value
                        self.kernels[node.name] = KernelDecl(
                            node.name, oracle, f.path, node.lineno)
            for sub in ast.walk(f.tree):
                tgt = None
                if isinstance(sub, (ast.Assign, ast.AugAssign)):
                    tgts = (sub.targets if isinstance(sub, ast.Assign)
                            else [sub.target])
                    for t in tgts:
                        if isinstance(t, ast.Subscript) and isinstance(
                                t.value, ast.Name):
                            tgt = t.value.id
                elif isinstance(sub, ast.Delete):
                    for t in sub.targets:
                        if isinstance(t, ast.Subscript) and isinstance(
                                t.value, ast.Name):
                            tgt = t.value.id
                if tgt:
                    mutated.add(tgt)
        self.mutable_globals = literal_globals & mutated


# -- AST helpers shared by the passes ----------------------------------------

def iter_functions(tree: ast.Module) -> Iterable[tuple[ast.AST, str]]:
    """Top-level and class-level functions as (node, qualname)."""
    for node in tree.body:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node, node.name
        elif isinstance(node, ast.ClassDef):
            for sub in node.body:
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    yield sub, f"{node.name}.{sub.name}"


def _dec_is(dec: ast.AST, name: str) -> bool:
    return (isinstance(dec, ast.Name) and dec.id == name) or (
        isinstance(dec, ast.Attribute) and dec.attr == name)


def _dec_mentions(dec: ast.AST, name: str) -> bool:
    """True if a decorator expression references ``name`` anywhere —
    catches ``@jax.jit``, ``@partial(jax.jit, ...)``, ``@jit``."""
    for sub in ast.walk(dec):
        if isinstance(sub, ast.Name) and sub.id == name:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr == name:
            return True
    return False


def mentions(node: ast.AST, names: set[str]) -> bool:
    """Does the subtree reference any of ``names`` (Name or Attribute)?"""
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in names:
            return True
        if isinstance(sub, ast.Attribute) and sub.attr in names:
            return True
    return False


def collect_aliases(func: ast.AST) -> tuple[set[str], dict[str, str]]:
    """Scan a function for ``x = <...>.col`` aliases and
    ``y = <...>.col["name"]`` column aliases.  Returns
    (col-dict alias names, column-array alias name → column name)."""
    col_aliases: set[str] = set()
    column_of: dict[str, str] = {}
    simple = [
        sub for sub in ast.walk(func)
        if isinstance(sub, ast.Assign) and len(sub.targets) == 1
        and isinstance(sub.targets[0], ast.Name)]
    for sub in simple:          # phase 1: dict aliases (c = store.col)
        if isinstance(sub.value, ast.Attribute) and sub.value.attr == "col":
            col_aliases.add(sub.targets[0].id)
    for sub in simple:          # phase 2: column aliases (w = c["x"])
        col = resolve_col(sub.value, col_aliases, {})
        if col is not None:
            column_of[sub.targets[0].id] = col
    return col_aliases, column_of


def resolve_col(node: ast.AST, col_aliases: set[str],
                column_of: dict[str, str]) -> Optional[str]:
    """Column name if ``node`` denotes a whole column array:
    ``<...>.col["name"]``, ``alias["name"]``, or a column alias Name."""
    if isinstance(node, ast.Name):
        return column_of.get(node.id)
    if isinstance(node, ast.Subscript):
        base, key = node.value, node.slice
        is_col_dict = (isinstance(base, ast.Attribute) and base.attr == "col"
                       ) or (isinstance(base, ast.Name)
                             and base.id in col_aliases)
        if is_col_dict and isinstance(key, ast.Constant) and isinstance(
                key.value, str):
            return key.value
    return None


@dataclasses.dataclass(frozen=True)
class ColWrite:
    column: str
    node: ast.AST             # the write statement / call
    value: Optional[ast.AST]  # RHS for assignments, None for ufunc.at


def col_writes(func: ast.AST) -> list[ColWrite]:
    """Every write to a named store/table column inside ``func``:
    subscript assignment, aug-assignment, whole-column assignment, and
    ``np.<ufunc>.at`` scatter calls — through one level of aliasing."""
    col_aliases, column_of = collect_aliases(func)
    writes: list[ColWrite] = []

    def target_col(t: ast.AST) -> Optional[str]:
        col = resolve_col(t, col_aliases, column_of)
        if col is None and isinstance(t, ast.Subscript):
            col = resolve_col(t.value, col_aliases, column_of)
        return col

    for sub in ast.walk(func):
        if isinstance(sub, ast.Assign):
            for t in sub.targets:
                col = target_col(t)
                if col is not None:
                    # skip the aliasing assignment itself (x = c["a"])
                    if isinstance(t, ast.Name):
                        continue
                    writes.append(ColWrite(col, sub, sub.value))
        elif isinstance(sub, ast.AugAssign):
            col = target_col(sub.target)
            if col is not None:
                writes.append(ColWrite(col, sub, sub.value))
        elif isinstance(sub, ast.Call):
            fn = sub.func
            if (isinstance(fn, ast.Attribute) and fn.attr == "at"
                    and isinstance(fn.value, ast.Attribute)
                    and fn.value.attr in _UFUNC_AT and sub.args):
                col = resolve_col(sub.args[0], col_aliases, column_of)
                if col is not None:
                    writes.append(ColWrite(col, sub, None))
    return writes


def followed_by_invalidation(func: ast.AST, write: ast.AST) -> bool:
    """True when the statement containing ``write`` is followed — in
    its own suite or any enclosing suite of ``func`` — by a direct
    ``<...>.mark_dirty()`` / ``adopt_device(...)`` /
    ``_membership_changed()`` call, or when the containing statement
    itself ends in one (compound one-liners).  Conditional siblings
    (an ``if`` wrapping the call) do NOT count: the invalidation must
    be unconditional on the write's own path."""
    path = _statement_path(func, write)
    if path is None:
        return False
    for suite, idx in reversed(path):
        for stmt in suite[idx + 1:]:
            if _is_invalidation_stmt(stmt):
                return True
    return False


def _is_invalidation_stmt(stmt: ast.AST) -> bool:
    if isinstance(stmt, ast.Expr) and isinstance(stmt.value, ast.Call):
        fn = stmt.value.func
        name = fn.attr if isinstance(fn, ast.Attribute) else (
            fn.id if isinstance(fn, ast.Name) else None)
        return name in INVALIDATORS
    return False


def _statement_path(func: ast.AST, target: ast.AST
                    ) -> Optional[list[tuple[list, int]]]:
    """Suite chain [(suite, index), ...] from the function body down to
    the statement containing ``target``."""

    def search(suite: list) -> Optional[list[tuple[list, int]]]:
        for i, stmt in enumerate(suite):
            if stmt is target or any(sub is target for sub in ast.walk(stmt)):
                for field in ("body", "orelse", "finalbody"):
                    child = getattr(stmt, field, None)
                    if isinstance(child, list) and child:
                        deeper = search(child)
                        if deeper is not None and any(
                                sub is target
                                for s in child for sub in ast.walk(s)):
                            return [(suite, i)] + deeper
                if isinstance(stmt, ast.Try):
                    for handler in stmt.handlers:
                        deeper = search(handler.body)
                        if deeper is not None:
                            return [(suite, i)] + deeper
                return [(suite, i)]
        return None

    return search(func.body)


# -- pass registry ------------------------------------------------------------

class Pass:
    """Base class: subclasses set ``rule``/``description`` and
    implement :meth:`run`."""

    rule: str = ""
    description: str = ""

    def run(self, project: Project) -> list[Finding]:  # pragma: no cover
        raise NotImplementedError


PASS_REGISTRY: dict[str, type] = {}


def register_pass(cls: type) -> type:
    PASS_REGISTRY[cls.rule] = cls
    return cls


# -- report -------------------------------------------------------------------

@dataclasses.dataclass
class Report:
    findings: list[Finding]
    #: waivers missing a ``-- reason`` (strict-mode error), as
    #: (path, line, rules)
    reasonless_waivers: list[tuple[str, int, tuple[str, ...]]]
    rules_run: tuple[str, ...]
    files_scanned: int

    @property
    def unwaived(self) -> list[Finding]:
        return [f for f in self.findings if not f.waived]

    @property
    def waived(self) -> list[Finding]:
        return [f for f in self.findings if f.waived]

    def ok(self, strict: bool = False) -> bool:
        if self.unwaived:
            return False
        if strict and self.reasonless_waivers:
            return False
        return True

    def to_json(self) -> dict:
        rules: dict[str, dict] = {
            r: {"findings": 0, "waived": 0} for r in self.rules_run}
        for f in self.findings:
            entry = rules.setdefault(f.rule, {"findings": 0, "waived": 0})
            entry["findings"] += 1
            if f.waived:
                entry["waived"] += 1
        return {
            "rules": rules,
            "files_scanned": self.files_scanned,
            "unwaived_total": len(self.unwaived),
            "reasonless_waivers": [
                {"path": p, "line": ln, "rules": list(rs)}
                for p, ln, rs in self.reasonless_waivers],
            "findings": [dataclasses.asdict(f) for f in self.findings],
        }


def collect_sources(paths: Iterable[str]) -> list[SourceFile]:
    files: list[SourceFile] = []
    for p in paths:
        root = Path(p)
        candidates = sorted(root.rglob("*.py")) if root.is_dir() else [root]
        for c in candidates:
            files.append(SourceFile(str(c), c.read_text()))
    return files


def parse_tests(tests_dir: Optional[str]) -> dict[str, set[str]]:
    """Test file path → every identifier (Name id / Attribute attr /
    import name) referenced in it — the cross-reference table for the
    oracle-parity pass."""
    out: dict[str, set[str]] = {}
    if not tests_dir:
        return out
    root = Path(tests_dir)
    if not root.is_dir():
        return out
    for p in sorted(root.rglob("*.py")):
        idents: set[str] = set()
        try:
            tree = ast.parse(p.read_text(), filename=str(p))
        except SyntaxError:
            continue
        for sub in ast.walk(tree):
            if isinstance(sub, ast.Name):
                idents.add(sub.id)
            elif isinstance(sub, ast.Attribute):
                idents.add(sub.attr)
            elif isinstance(sub, (ast.Import, ast.ImportFrom)):
                for alias in sub.names:
                    idents.add(alias.name.rsplit(".", 1)[-1])
                    if alias.asname:
                        idents.add(alias.asname)
        out[str(p)] = idents
    return out


def analyze(paths: Iterable[str], *, manifest=None,
            tests_dir: Optional[str] = "tests",
            rules: Optional[Iterable[str]] = None) -> Report:
    """Run the registered passes over ``paths`` and apply waivers."""
    from repro.analysis import passes as _passes  # noqa: F401  (registers)
    from repro.analysis.manifest import default_manifest

    if manifest is None:
        manifest = default_manifest()
    files = collect_sources(paths)
    project = Project(files, manifest, tests=parse_tests(tests_dir))
    selected = tuple(rules) if rules else tuple(sorted(PASS_REGISTRY))
    unknown = set(selected) - set(PASS_REGISTRY)
    if unknown:
        raise ValueError(f"unknown rules: {sorted(unknown)}")

    by_path = {f.path: f for f in files}
    findings: list[Finding] = []
    for rule in selected:
        for raw in PASS_REGISTRY[rule]().run(project):
            src = by_path.get(raw.path)
            w = src.waiver_for(raw.rule, raw.line) if src else None
            if w is not None:
                raw = dataclasses.replace(
                    raw, waived=True,
                    waive_reason=w.reason or "(no reason given)")
            findings.append(raw)

    reasonless = [
        (f.path, w.line, w.rules)
        for f in files for w in f.waivers if not w.reason]
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return Report(findings=findings, reasonless_waivers=reasonless,
                  rules_run=selected, files_scanned=len(files))
