"""Pass 1 — ``mirror-invalidation``.

The resident store mirrors its kernel-facing columns as a cached
device ``ControlState``.  A host-side write to a mirrored column that
is not followed by ``mark_dirty()`` (or ``adopt_device`` /
``_membership_changed``) on its own suite chain silently feeds STALE
burst/debt to every later admission kernel — the worst control-plane
failure mode, invisible to parity tests that run on fresh stores.

Flags every assignment / aug-assignment / ``np.<ufunc>.at`` scatter
targeting a mirrored column (per the column manifest, through one
level of ``x = store.col`` / ``w = c["burst"]`` aliasing) unless the
write is inside a sanctioned mutator or an invalidation call follows
it unconditionally.  Dynamic keys (``c[name][slot] = v``) are out of
scope — the repo's only such site is ``_col_property``, whose
``dirty=True`` variant invalidates by construction.
"""
from __future__ import annotations

from repro.analysis.core import (
    Finding,
    Pass,
    Project,
    col_writes,
    followed_by_invalidation,
    iter_functions,
    register_pass,
)


@register_pass
class MirrorInvalidationPass(Pass):
    rule = "mirror-invalidation"
    description = ("writes to device-mirrored store columns must be "
                   "followed by mark_dirty()/adopt_device()")

    def run(self, project: Project) -> list[Finding]:
        mirrored = project.manifest.mirrored
        sanctioned = project.manifest.sanctioned_mutators
        findings: list[Finding] = []
        for f in project.files:
            for func, qualname in iter_functions(f.tree):
                if qualname in sanctioned:
                    continue
                for w in col_writes(func):
                    if w.column not in mirrored:
                        continue
                    if followed_by_invalidation(func, w.node):
                        continue
                    findings.append(Finding(
                        rule=self.rule, path=f.path, line=w.node.lineno,
                        message=(
                            f"write to device-mirrored column "
                            f"{w.column!r} in {qualname} is not followed "
                            f"by mark_dirty()/adopt_device() — the cached "
                            f"ControlState goes stale")))
        return findings
