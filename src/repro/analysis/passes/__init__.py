"""The seven control-plane invariant passes.  Importing this package
registers them all with ``repro.analysis.core.PASS_REGISTRY``."""
from repro.analysis.passes import (  # noqa: F401
    chaos_api,
    dtype,
    hotpath,
    mirror,
    parity,
    retrace,
    telemetry,
)
