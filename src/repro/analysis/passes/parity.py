"""Pass 5 — ``oracle-parity``.

Every jit kernel in the control plane must be pinned against a
retained scalar oracle.  Mechanically:

* a jit-decorated function under ``repro/core`` or ``repro/gateway``
  must carry ``@kernel(oracle="<dotted path>")`` (the registration
  decorator from ``repro.core.markers`` — zero overhead at call time);
* for every registered kernel there must exist a test module under
  ``tests/`` that references BOTH the kernel name and its oracle (the
  terminal symbol of the dotted path, or the class when the oracle is
  a method) — delete a kernel's parity test and this pass fails CI.

Model/serving jit code (``repro/kernels``, ``repro/serving``, ...) is
outside the control-plane contract and exempt from registration.
"""
from __future__ import annotations

from repro.analysis.core import Finding, Pass, Project, register_pass

#: path fragments whose jit functions MUST register an oracle.
REGISTRATION_SCOPE = ("repro/core/", "repro/gateway/")


def _oracle_symbols(oracle: str) -> set[str]:
    parts = oracle.split(".")
    symbols = {parts[-1]}
    if len(parts) > 1 and parts[-2][:1].isupper():
        symbols.add(parts[-2])      # method oracle: the class counts too
    return symbols


@register_pass
class OracleParityPass(Pass):
    rule = "oracle-parity"
    description = ("every control-plane jit kernel registers a scalar "
                   "oracle and has a parity test referencing both")

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for jd in project.jit_defs:
            in_scope = any(s in jd.file.path.replace("\\", "/")
                           for s in REGISTRATION_SCOPE)
            if in_scope and jd.node.name not in project.kernels:
                findings.append(Finding(
                    rule=self.rule, path=jd.file.path, line=jd.node.lineno,
                    message=(
                        f"jit kernel {jd.node.name!r} is not registered "
                        f"via @kernel(oracle=...) — every control-plane "
                        f"kernel needs a scalar parity oracle")))
        for decl in project.kernels.values():
            if decl.oracle is None:
                findings.append(Finding(
                    rule=self.rule, path=decl.path, line=decl.line,
                    message=(
                        f"@kernel on {decl.name!r} has no literal "
                        f"oracle=\"<dotted path>\" argument")))
                continue
            symbols = _oracle_symbols(decl.oracle)
            covered = any(
                decl.name in idents and (symbols & idents)
                for idents in project.tests.values())
            if not covered:
                findings.append(Finding(
                    rule=self.rule, path=decl.path, line=decl.line,
                    message=(
                        f"no test module references both kernel "
                        f"{decl.name!r} and its oracle "
                        f"{decl.oracle!r} — parity coverage missing")))
        return findings
