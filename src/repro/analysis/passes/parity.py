"""Pass 5 — ``oracle-parity``.

Every jit kernel in the control plane must be pinned against a
retained scalar oracle.  Mechanically:

* a jit-decorated function under ``repro/core`` or ``repro/gateway``
  must carry ``@kernel(oracle="<dotted path>")`` (the registration
  decorator from ``repro.core.markers`` — zero overhead at call time);
* for every registered kernel there must exist a test module under
  ``tests/`` that references BOTH the kernel name and its oracle (the
  terminal symbol of the dotted path, or the class when the oracle is
  a method) — delete a kernel's parity test and this pass fails CI;
* a jit function whose body calls ``shard_map`` is a SHARDED kernel —
  it must register an oracle wherever it lives (``distributed/`` and
  ``models/`` included): multi-device decisions are pinned against
  the single-device kernel, which is itself pinned against the scalar
  oracle (``core.shard_plane`` is the template).

Model/serving jit code (``repro/kernels``, ``repro/serving``, ...) is
otherwise outside the control-plane contract and exempt.
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, Pass, Project, register_pass

#: path fragments whose jit functions MUST register an oracle.
REGISTRATION_SCOPE = ("repro/core/", "repro/gateway/")


def _uses_shard_map(func: ast.AST) -> bool:
    for sub in ast.walk(func):
        if isinstance(sub, ast.Call):
            f = sub.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else None)
            if name == "shard_map":
                return True
    return False


def _oracle_symbols(oracle: str) -> set[str]:
    parts = oracle.split(".")
    symbols = {parts[-1]}
    if len(parts) > 1 and parts[-2][:1].isupper():
        symbols.add(parts[-2])      # method oracle: the class counts too
    return symbols


@register_pass
class OracleParityPass(Pass):
    rule = "oracle-parity"
    description = ("every control-plane jit kernel registers a scalar "
                   "oracle and has a parity test referencing both")

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for jd in project.jit_defs:
            in_scope = any(s in jd.file.path.replace("\\", "/")
                           for s in REGISTRATION_SCOPE)
            if jd.node.name in project.kernels:
                continue
            if in_scope:
                findings.append(Finding(
                    rule=self.rule, path=jd.file.path, line=jd.node.lineno,
                    message=(
                        f"jit kernel {jd.node.name!r} is not registered "
                        f"via @kernel(oracle=...) — every control-plane "
                        f"kernel needs a scalar parity oracle")))
            elif _uses_shard_map(jd.node):
                findings.append(Finding(
                    rule=self.rule, path=jd.file.path, line=jd.node.lineno,
                    message=(
                        f"sharded jit kernel {jd.node.name!r} (shard_map "
                        f"body) is not registered via @kernel(oracle=...) "
                        f"— multi-device decisions must be pinned against "
                        f"the single-device kernel")))
        for decl in project.kernels.values():
            if decl.oracle is None:
                findings.append(Finding(
                    rule=self.rule, path=decl.path, line=decl.line,
                    message=(
                        f"@kernel on {decl.name!r} has no literal "
                        f"oracle=\"<dotted path>\" argument")))
                continue
            symbols = _oracle_symbols(decl.oracle)
            covered = any(
                decl.name in idents and (symbols & idents)
                for idents in project.tests.values())
            if not covered:
                findings.append(Finding(
                    rule=self.rule, path=decl.path, line=decl.line,
                    message=(
                        f"no test module references both kernel "
                        f"{decl.name!r} and its oracle "
                        f"{decl.oracle!r} — parity coverage missing")))
        return findings
