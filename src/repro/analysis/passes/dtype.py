"""Pass 2 — ``dtype-discipline``.

The resident contract (``resident.py``): kernel-facing columns are
float32 (the kernels and every parity oracle run f32 end-to-end), the
accounting accumulators are float64 (sequential-accumulation parity
with the scalar bookkeeping, bit for bit).  Two violation shapes:

* an f64 value flowing into a registered jit kernel argument — either
  an explicit ``float64`` dtype/cast in the argument expression, or an
  f64 accumulator column passed through uncast (jit would weak-promote
  or retrace, and parity drifts);
* an f32 truncation written INTO an f64 accumulator column — the
  sequential-accumulation parity the f64 contract exists for is lost.

Both are seeded from the column manifest; kernel call sites are the
functions registered via ``@kernel(oracle=...)``.
"""
from __future__ import annotations

import ast
from typing import Optional

from repro.analysis.core import (
    Finding,
    Pass,
    Project,
    col_writes,
    collect_aliases,
    iter_functions,
    register_pass,
    resolve_col,
)

#: calls accepted as an explicit down-cast to f32 when they mention
#: float32 anywhere in their arguments: .astype(...), np.float32(...),
#: np.asarray(x, np.float32), jnp.asarray(...)
_CAST_FUNCS = ("astype", "asarray", "array", "float32")


def _is_f32_cast(node: ast.AST) -> bool:
    if not isinstance(node, ast.Call):
        return False
    fn = node.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None)
    if name not in _CAST_FUNCS:
        return False
    if name == "float32":
        return True
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Attribute) and sub.attr == "float32") or (
                isinstance(sub, ast.Name) and sub.id == "float32"):
            return True
    return False


def _mentions_f32(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if (isinstance(sub, ast.Attribute) and sub.attr == "float32") or (
                isinstance(sub, ast.Name) and sub.id == "float32"):
            return True
    return False


def _call_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return None


def kernel_calls(func: ast.AST, kernels: set[str]) -> list[ast.Call]:
    return [sub for sub in ast.walk(func)
            if isinstance(sub, ast.Call) and _call_name(sub) in kernels]


def _uncast_f64_cols(arg: ast.AST, col_aliases, column_of,
                     f64_cols: set[str]) -> list[str]:
    """f64 columns referenced in ``arg`` with no f32 cast wrapping them
    (checked top-down: a cast anywhere above the reference sanctions
    everything below it)."""
    hits: list[str] = []

    def visit(node: ast.AST) -> None:
        if _is_f32_cast(node):
            return
        col = resolve_col(node, col_aliases, column_of)
        if col in f64_cols:
            hits.append(col)
            return
        for child in ast.iter_child_nodes(node):
            visit(child)

    visit(arg)
    return hits


@register_pass
class DtypeDisciplinePass(Pass):
    rule = "dtype-discipline"
    description = ("no f64 into jit kernel args; no f32 truncation of "
                   "f64 accumulator columns")

    def run(self, project: Project) -> list[Finding]:
        f64_cols = project.manifest.f64_columns
        kernels = set(project.kernels)
        findings: list[Finding] = []
        for f in project.files:
            for func, qualname in iter_functions(f.tree):
                if func.name in kernels:
                    continue        # kernels compose internally
                col_aliases, column_of = collect_aliases(func)
                for call in kernel_calls(func, kernels):
                    args = list(call.args) + [
                        kw.value for kw in call.keywords]
                    for arg in args:
                        for sub in ast.walk(arg):
                            if (isinstance(sub, ast.Attribute)
                                    and sub.attr == "float64") or (
                                    isinstance(sub, ast.Name)
                                    and sub.id == "float64"):
                                findings.append(Finding(
                                    rule=self.rule, path=f.path,
                                    line=arg.lineno,
                                    message=(
                                        f"float64 value flows into jit "
                                        f"kernel {_call_name(call)!r} "
                                        f"argument in {qualname} (f32 "
                                        f"kernel contract)")))
                                break
                        for col in _uncast_f64_cols(
                                arg, col_aliases, column_of, f64_cols):
                            findings.append(Finding(
                                rule=self.rule, path=f.path,
                                line=arg.lineno,
                                message=(
                                    f"f64 accumulator column {col!r} "
                                    f"passed uncast to jit kernel "
                                    f"{_call_name(call)!r} in {qualname} "
                                    f"— cast to float32 explicitly")))
                for w in col_writes(func):
                    if w.column in f64_cols and w.value is not None and \
                            _mentions_f32(w.value):
                        findings.append(Finding(
                            rule=self.rule, path=f.path,
                            line=w.node.lineno,
                            message=(
                                f"f32-truncated value written into f64 "
                                f"accumulator column {w.column!r} in "
                                f"{qualname} — breaks sequential-"
                                f"accumulation parity")))
        return findings
