"""Pass 7 — ``chaos-public-api``.

The chaos harness (``src/repro/chaos/``) observes and perturbs the
control plane FROM OUTSIDE: scenarios inject churn through
``TokenPool.add_entitlement`` / ``PoolManager.migrate_entitlement``,
checkers read ``TokenPool.audit_snapshot()`` / ``Ledger.level_audit``.
If the harness ever reached into private state (``pool._authorized``,
``store._free``, a stray ``col["bucket_level"]`` poke through a
private handle), its invariants would assert implementation details
instead of the public contract — and a checker could itself corrupt
the state it audits.

The pass flags any ``_``-prefixed attribute access (read or write) on
a value other than ``self``/``cls`` inside the chaos package.  Dunder
attributes are exempt (they are protocol, not privacy).  Tests are
NOT covered — the deliberately-broken fixtures in ``test_chaos.py``
poke private columns on purpose to prove each checker fires.

A justified exception takes a line waiver::

    x = pool._authorized  # repro: allow[chaos-public-api] -- <why>
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, Pass, Project, register_pass

#: path fragment selecting the harness package
CHAOS_FRAGMENT = "repro/chaos/"


@register_pass
class ChaosPublicApiPass(Pass):
    rule = "chaos-public-api"
    description = ("the chaos harness must drive the control plane "
                   "through public entry points only — no private "
                   "attribute reach-ins")

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for f in project.files:
            path = f.path.replace("\\", "/")
            if CHAOS_FRAGMENT not in path:
                continue
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Attribute):
                    continue
                attr = node.attr
                if not attr.startswith("_") or attr.startswith("__"):
                    continue
                base = node.value
                if isinstance(base, ast.Name) \
                        and base.id in ("self", "cls"):
                    continue
                findings.append(Finding(
                    rule=self.rule, path=f.path, line=node.lineno,
                    message=(
                        f"private attribute .{attr} accessed from the "
                        f"chaos harness — use the public TokenPool/"
                        f"Ledger/simulator surface (audit_snapshot, "
                        f"level_audit, row_accounting, step_hooks) or "
                        f"waive with a reason")))
        return findings
