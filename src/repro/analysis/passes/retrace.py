"""Pass 3 — ``retrace-hazard``.

jit shapes are static: every kernel entry point must see pow2-bucketed
widths (``bucket_width`` rows, ``quantum_width`` requests) or churn
retraces the kernel on every membership/quantum-size change — the
no-retrace pins in ``tests/test_resident.py`` guard the runtime side
via ``TRACE_COUNTS`` (see ``repro.analysis.runtime.assert_no_retrace``
for the cross-check helper); this pass guards it statically.  Three
hazard shapes, all reported under one rule:

* **unbucketed call** — a call site of a registered kernel in a
  function that never touches a shape-bucketing provider
  (``bucket_width`` / ``quantum_width`` / ``pad_state`` / the resident
  store's cached views, which are pow2 by construction);
* **static-argnames hygiene** — a kernel's ``static_argnames`` must be
  a literal tuple of string constants, and call sites must not pass
  unhashable literals (list/dict/set) for a static arg: each distinct
  static value is a fresh trace, unhashables are a TypeError;
* **mutable host capture** — a kernel (or a local function it calls)
  reads or writes a module-level dict/list/set that the project
  mutates: the closure captures trace-time state that silently
  diverges from runtime (the deliberate ``TRACE_COUNTS`` trace
  counters carry explicit waivers);
* **inline mesh construction** — a ``shard_map`` kernel call site
  passing ``mesh=Mesh(...)`` built in place: ``mesh`` is a static jit
  argument, so every fresh ``Mesh`` object fragments the dispatch
  cache — meshes must come from the cached providers
  (``row_mesh`` / ``pool_mesh`` in ``core.shard_plane``).
"""
from __future__ import annotations

import ast

from repro.analysis.core import (
    Finding,
    Pass,
    Project,
    iter_functions,
    mentions,
    register_pass,
)
from repro.analysis.passes.dtype import _call_name, kernel_calls

#: functions/attributes that yield pow2-bucketed shapes: the padding
#: helpers themselves plus the resident-store views that are pow2 by
#: construction (store capacity is a bucket_width).
SHAPE_PROVIDERS = {
    "bucket_width", "quantum_width", "pad_rows", "pad_state",
    "stack_states", "device_state", "_kernel_inputs", "_arrays",
    "arrays_from_pool", "quantum_snapshot",
    # sharded plane: mesh-aligned pow2 widths and the cached meshes
    # (``core.shard_plane``)
    "shard_width", "row_mesh", "pool_mesh",
}


def _static_argnames(func: ast.AST):
    """(decorator keyword node, [names] or None-if-non-literal) for a
    jit decoration carrying static_argnames, else (None, None)."""
    for dec in func.decorator_list:
        if not isinstance(dec, ast.Call):
            continue
        for kw in dec.keywords:
            if kw.arg != "static_argnames":
                continue
            v = kw.value
            if isinstance(v, ast.Constant) and isinstance(v.value, str):
                return kw, [v.value]
            if isinstance(v, (ast.Tuple, ast.List)) and all(
                    isinstance(e, ast.Constant) and isinstance(e.value, str)
                    for e in v.elts):
                return kw, [e.value for e in v.elts]
            return kw, None
    return None, None


@register_pass
class RetraceHazardPass(Pass):
    rule = "retrace-hazard"
    description = ("kernel call sites must shape-bucket; static args "
                   "literal+hashable; no mutable host capture")

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        kernels = set(project.kernels)
        statics: dict[str, list[str]] = {}

        # -- kernel definitions: static_argnames + mutable capture ----
        for jd in project.jit_defs:
            if jd.node.name not in kernels:
                continue
            kw, names = _static_argnames(jd.node)
            if kw is not None and names is None:
                findings.append(Finding(
                    rule=self.rule, path=jd.file.path, line=kw.value.lineno,
                    message=(
                        f"static_argnames of kernel {jd.node.name!r} is "
                        f"not a literal tuple of strings — non-constant "
                        f"static specs hide retrace behavior")))
            statics[jd.node.name] = names or []

            module_funcs = {
                n.name: n for n, q in iter_functions(jd.file.tree)
                if "." not in q}
            bodies = [jd.node]
            for sub in ast.walk(jd.node):     # one local-call hop
                if isinstance(sub, ast.Call) and isinstance(
                        sub.func, ast.Name) and sub.func.id in module_funcs:
                    callee = module_funcs[sub.func.id]
                    if callee not in bodies:
                        bodies.append(callee)
            seen: set[tuple[str, int]] = set()
            for body in bodies:
                for sub in ast.walk(body):
                    if isinstance(sub, ast.Name) and \
                            sub.id in project.mutable_globals:
                        key = (sub.id, sub.lineno)
                        if key in seen:
                            continue
                        seen.add(key)
                        findings.append(Finding(
                            rule=self.rule, path=jd.file.path,
                            line=sub.lineno,
                            message=(
                                f"kernel {jd.node.name!r} (via "
                                f"{body.name!r}) captures mutable host "
                                f"state {sub.id!r} — executes at trace "
                                f"time only, diverges from runtime")))

        # -- call sites ------------------------------------------------
        for f in project.files:
            for func, qualname in iter_functions(f.tree):
                if func.name in kernels:
                    continue        # kernels composing kernels is fine
                calls = kernel_calls(func, kernels)
                if not calls:
                    continue
                bucketed = mentions(func, SHAPE_PROVIDERS)
                for call in calls:
                    kname = _call_name(call)
                    if not bucketed:
                        findings.append(Finding(
                            rule=self.rule, path=f.path, line=call.lineno,
                            message=(
                                f"call to jit kernel {kname!r} in "
                                f"{qualname} without bucket_width/"
                                f"quantum_width padding — array-shape "
                                f"churn retraces the kernel")))
                    for kw in call.keywords:
                        if kw.arg in statics.get(kname, ()) and isinstance(
                                kw.value,
                                (ast.List, ast.Dict, ast.Set)):
                            findings.append(Finding(
                                rule=self.rule, path=f.path,
                                line=kw.value.lineno,
                                message=(
                                    f"unhashable literal passed as "
                                    f"static arg {kw.arg!r} of kernel "
                                    f"{kname!r} in {qualname}")))
                        if kw.arg == "mesh" and isinstance(
                                kw.value, ast.Call) and \
                                _call_name(kw.value) == "Mesh":
                            findings.append(Finding(
                                rule=self.rule, path=f.path,
                                line=kw.value.lineno,
                                message=(
                                    f"inline Mesh(...) passed as static "
                                    f"mesh of kernel {kname!r} in "
                                    f"{qualname} — every fresh Mesh "
                                    f"object is a new dispatch-cache "
                                    f"entry; use the cached row_mesh/"
                                    f"pool_mesh providers")))
        return findings
