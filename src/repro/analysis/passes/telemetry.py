"""Pass 6 — ``telemetry-hot-path``.

Telemetry inside ``@hot_path`` functions must use the BATCH recording
APIs (``observe_rows`` / ``inc_rows`` / ``record_batch`` /
``incr_many``): one row-op per quantum.  The scalar twins re-introduce
exactly the per-row Python the vectorized lifecycle eliminated — a
10k-request quantum calling ``store.incr`` per key or
``histogram.observe`` per value is O(requests) dict/ufunc work on the
hot path.

The pass flags any call whose attribute name is a scalar recorder
(``observe``, ``incr``) inside a ``@hot_path`` function.  Scalar
recorders remain legal everywhere else — they are the parity oracles
and the cold-path convenience API.  A deliberate scalar call in a hot
path takes a line waiver::

    self.store.incr(k, 1.0, now)  # repro: allow[telemetry-hot-path] -- <why>
"""
from __future__ import annotations

import ast

from repro.analysis.core import Finding, Pass, Project, register_pass

#: scalar recording spellings forbidden in hot paths (their batch
#: twins — observe_rows / inc_rows / incr_many / record_batch — have
#: different attribute names and never match).
SCALAR_RECORDERS = {"observe", "incr"}


@register_pass
class TelemetryHotPathPass(Pass):
    rule = "telemetry-hot-path"
    description = ("@hot_path functions must record telemetry through "
                   "batch row-ops, not scalar observe()/incr()")

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for hp in project.hot_paths:
            for sub in ast.walk(hp.node):
                if not isinstance(sub, ast.Call):
                    continue
                fn = sub.func
                if isinstance(fn, ast.Attribute) \
                        and fn.attr in SCALAR_RECORDERS:
                    findings.append(Finding(
                        rule=self.rule, path=hp.file.path,
                        line=sub.lineno,
                        message=(
                            f"scalar recorder .{fn.attr}() in hot path "
                            f"{hp.qualname} — use the batch API "
                            f"(observe_rows/inc_rows/record_batch/"
                            f"incr_many) or waive with a reason")))
        return findings
