"""Pass 4 — ``hot-path-scalar-loop``.

Functions marked ``@hot_path`` are the vectorized per-quantum/per-tick
paths: their Python cost must be O(batch) or O(1), never O(rows).  The
pass flags any ``for`` loop or comprehension inside a hot path whose
iterable touches a store/table ROW container — the membership dicts
(``slot_of`` / ``rid_of`` / ``name_of``), the row-view facades
(``in_flight`` / ``status`` / ``entitlements``), the live-row caches,
or the legacy per-request dicts (``_charges`` / ``_buckets``).

Iterating the incoming batch (requests, completions, per-entitlement
group dicts) is fine — that's O(batch) by definition.  A hot path that
must walk rows for a documented reason takes a line waiver::

    for rid, slot in self.table.slot_of.items():  # repro: allow[hot-path-scalar-loop] -- <why>
"""
from __future__ import annotations

import ast

from repro.analysis.core import (
    Finding,
    Pass,
    Project,
    mentions,
    register_pass,
)

#: attribute/name spellings that denote per-row containers.
ROW_CONTAINERS = {
    "slot_of", "rid_of", "name_of", "in_flight", "status",
    "entitlements", "live_names", "live_slots", "_charges", "_buckets",
    "spill_from",
}

_LOOPS = (ast.For, ast.AsyncFor)
_COMPS = (ast.ListComp, ast.SetComp, ast.DictComp, ast.GeneratorExp)


@register_pass
class HotPathScalarLoopPass(Pass):
    rule = "hot-path-scalar-loop"
    description = ("@hot_path functions may not loop over store/table "
                   "rows in Python")

    def run(self, project: Project) -> list[Finding]:
        findings: list[Finding] = []
        for hp in project.hot_paths:
            for sub in ast.walk(hp.node):
                iters: list[ast.AST] = []
                if isinstance(sub, _LOOPS):
                    iters = [sub.iter]
                elif isinstance(sub, _COMPS):
                    iters = [g.iter for g in sub.generators]
                for it in iters:
                    if mentions(it, ROW_CONTAINERS):
                        kind = ("loop" if isinstance(sub, _LOOPS)
                                else "comprehension")
                        findings.append(Finding(
                            rule=self.rule, path=hp.file.path,
                            line=sub.lineno,
                            message=(
                                f"per-row Python {kind} over a "
                                f"store/table container in hot path "
                                f"{hp.qualname} — vectorize or waive "
                                f"with a reason")))
        return findings
