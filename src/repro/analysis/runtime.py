"""Runtime cross-check for the ``retrace-hazard`` pass.

The static pass asserts that kernel call sites shape-bucket their
arrays; the compiled truth lives in ``control_plane.TRACE_COUNTS``
(bumped at trace time by every kernel body).  This helper turns those
counters into an assertion so tests can sandwich a churn scenario and
prove the static claim holds at runtime::

    with assert_no_retrace("admit_quantum"):
        for _ in range(64):
            gateway.handle_quantum(requests(), now)
"""
from __future__ import annotations

import contextlib


@contextlib.contextmanager
def assert_no_retrace(*names: str):
    """Assert the named ``TRACE_COUNTS`` entries (default: all) do not
    move across the block — i.e. nothing inside compiled a new kernel
    variant.  Yields the starting counts."""
    from repro.core.control_plane import TRACE_COUNTS

    watch = names or tuple(TRACE_COUNTS)
    before = {n: TRACE_COUNTS[n] for n in watch}
    yield dict(before)
    moved = {n: (before[n], TRACE_COUNTS[n]) for n in watch
             if TRACE_COUNTS[n] != before[n]}
    if moved:
        raise AssertionError(
            f"kernel retraced inside no-retrace block "
            f"(name: before -> after): {moved}")
