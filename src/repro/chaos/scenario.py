"""Declarative, seeded chaos scenarios for the control plane.

A :class:`Scenario` is a frozen description of one incident timeline:
workload mix, pool fleet, and a schedule of scripted events (replica
failures, rate surges, entitlement churn, migrations).  It stores
*constructor kwargs* — not live ``Workload`` / ``PoolSite`` objects —
because the simulator mutates workloads in place (``set_rate``) and a
scenario must build an arbitrary number of fresh, identical simulators
(the differential-replay engine runs three per scenario).

Everything a scenario injects goes through PUBLIC control-plane entry
points: ``sim.at`` for the simulator-native event kinds, and ``call``
closures wrapping ``TokenPool.add_entitlement`` /
``TokenPool.remove_entitlement`` / ``PoolManager.migrate_entitlement``
for churn.  The ``chaos-public-api`` analysis pass enforces that this
module never reaches into private state.
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

from repro.core import EntitlementSpec, QoS, Resources
from repro.serving.simulation import MultiPoolSimulator, PoolSite, Workload

#: event kinds the simulator handles natively (payload forwarded as-is)
SIM_EVENTS = frozenset({"fail_replica", "recover_replica", "set_rate"})
#: event kinds the harness lowers to ``call`` closures
HARNESS_EVENTS = frozenset(
    {"add_entitlement", "remove_entitlement", "migrate"})


@dataclasses.dataclass(frozen=True)
class ScenarioEvent:
    """One scripted incident at simulated time ``t``.

    Kinds and payloads:

    - ``fail_replica`` / ``recover_replica`` — ``pool``, ``idx``
    - ``set_rate`` — ``workload``, ``rate`` (rps, effective next arrival)
    - ``add_entitlement`` — ``pool``, ``name``, ``service_class``,
      ``slo_ms``, ``tokens_per_second``, ``slots``
    - ``remove_entitlement`` — ``pool``, ``name``
    - ``migrate`` — ``entitlement``, ``src``, ``dst``
    """

    t: float
    kind: str
    payload: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A seeded, fully reproducible incident timeline.

    ``workloads`` / ``sites`` are tuples of constructor-kwargs dicts
    for :class:`Workload` / :class:`PoolSite`; :func:`build_sim`
    instantiates fresh objects per simulator so replays never share
    mutable state.
    """

    name: str
    seed: int
    duration_s: float
    workloads: tuple = ()          # tuple[dict] — Workload kwargs
    sites: tuple = ()              # tuple[dict] — PoolSite kwargs
    events: tuple = ()             # tuple[ScenarioEvent]
    dt: float = 0.02
    accounting_interval_s: float = 1.0
    bucket_window_s: float = 4.0
    spill_policy: str = "static"
    autoscale: bool = False
    provision_lag_s: float = 2.0
    drain_s: float = 2.0
    #: Experiment-1 bound asserted by the guaranteed-p99 final checker
    #: (None → checker skips this scenario)
    p99_bound_s: Optional[float] = None
    #: deterministic client backoff: base + jitter drawn from a crc32
    #: hash of (seed, workload, attempt) — NOT ``hash()``, which varies
    #: under PYTHONHASHSEED and would unpin the retry timeline
    retry_base_s: float = 0.25
    retry_jitter_s: float = 0.5
    description: str = ""


def seeded_backoff(scenario: Scenario):
    """Deterministic retry backoff for differential replay.

    Retry-After *hints* legitimately differ between the scalar and
    quantum admission paths (documented in ``Gateway.handle_quantum``),
    so a replayable scenario must not let the hint drive the retry
    timeline.  This substitutes a pure function of
    (scenario seed, workload, attempt): identical across the scalar,
    quantum and fast-path runs by construction.
    """

    def backoff(w, req, attempt, resp) -> float:
        h = zlib.crc32(f"{scenario.seed}:{w.name}:{attempt}".encode())
        return scenario.retry_base_s \
            + scenario.retry_jitter_s * ((h % 997) / 997.0)

    return backoff


def _add_entitlement_fn(p: dict):
    def fn(sim, now):
        sim.manager.pool(p["pool"]).add_entitlement(EntitlementSpec(
            name=p["name"], tenant_id=p.get("tenant_id", p["name"]),
            pool=p["pool"],
            qos=QoS(service_class=p["service_class"],
                    slo_target_ms=p.get("slo_ms", 1000.0)),
            baseline=Resources(p.get("tokens_per_second", 0.0), 0.0,
                               p.get("slots", 1.0))), now=now)
    return fn


def _remove_entitlement_fn(p: dict):
    def fn(sim, now):
        sim.manager.pool(p["pool"]).remove_entitlement(p["name"], now)
    return fn


def _migrate_fn(p: dict):
    def fn(sim, now):
        sim.manager.migrate_entitlement(
            p["entitlement"], p["src"], p["dst"], now)
    return fn


def schedule_event(sim: MultiPoolSimulator, ev: ScenarioEvent) -> None:
    """Lower one :class:`ScenarioEvent` onto the simulator's event
    queue — native kinds pass through, harness kinds become ``call``
    closures over public control-plane entry points."""
    if ev.kind in SIM_EVENTS:
        sim.at(ev.t, ev.kind, **dict(ev.payload))
    elif ev.kind == "add_entitlement":
        sim.at(ev.t, "call", fn=_add_entitlement_fn(dict(ev.payload)))
    elif ev.kind == "remove_entitlement":
        sim.at(ev.t, "call", fn=_remove_entitlement_fn(dict(ev.payload)))
    elif ev.kind == "migrate":
        sim.at(ev.t, "call", fn=_migrate_fn(dict(ev.payload)))
    else:
        raise ValueError(f"unknown scenario event kind {ev.kind!r}")


def build_sim(scenario: Scenario, admission_mode: str = "quantum",
              quantum_fast: bool = True,
              telemetry=True) -> MultiPoolSimulator:
    """Materialize one simulator for ``scenario``.

    Fresh ``Workload`` / ``PoolSite`` objects are built per call
    (``set_rate`` events mutate workloads in place), the deterministic
    retry backoff is installed, and every scripted event is scheduled.
    ``admission_mode`` / ``quantum_fast`` select the admission pipeline
    under test — the replay engine calls this three times with the
    same scenario and diffs the resulting decision traces.
    """
    workloads = [Workload(**dict(kw)) for kw in scenario.workloads]
    sites = [PoolSite(**dict(kw)) for kw in scenario.sites]
    sim = MultiPoolSimulator(
        workloads, sites, dt=scenario.dt, seed=scenario.seed,
        accounting_interval_s=scenario.accounting_interval_s,
        bucket_window_s=scenario.bucket_window_s,
        spill_policy=scenario.spill_policy,
        admission_mode=admission_mode,
        autoscale=scenario.autoscale,
        provision_lag_s=scenario.provision_lag_s,
        drain_s=scenario.drain_s,
        telemetry=telemetry)
    sim.gateway.quantum_fast_enabled = quantum_fast
    sim.retry_backoff = seeded_backoff(scenario)
    for ev in scenario.events:
        schedule_event(sim, ev)
    return sim
