"""Differential replay: one scenario, three admission pipelines.

The same seeded :class:`~repro.chaos.scenario.Scenario` is executed
under (a) scalar per-request admission (``Gateway.handle`` — the
parity oracle), (b) the generic quantum path
(``Gateway.handle_quantum`` with the fast path disabled), and (c) the
fused fast path.  The three runs must be **decision-identical**: every
request gets the same terminal state, deny reason, admitting pool and
spill-hop count, and the flight recorder (PR 8's admission black box)
must hold structurally identical per-request decision traces — same
legs, same verdicts, same reason codes.  Numeric trace fields
(priority) are compared under an f32 tolerance because the kernel path
computes in float32 while the scalar oracle uses float64.

Retry-After *hints* are the one sanctioned divergence between modes,
so :func:`~repro.chaos.scenario.build_sim` pins the client retry
timeline with a deterministic seeded backoff — hint differences can
then never desynchronize the arrival sequences.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.chaos.scenario import Scenario, build_sim

#: (label, admission_mode, quantum_fast)
REPLAY_MODES = (
    ("scalar", "scalar", False),
    ("quantum", "quantum", False),
    ("quantum_fast", "quantum", True),
)

#: f32-vs-f64 slack for priorities recorded along the two pipelines
PRIORITY_RTOL = 1e-4
PRIORITY_ATOL = 1e-3


@dataclasses.dataclass(frozen=True)
class RequestOutcome:
    """Decision-relevant terminal facts for one request."""

    request_id: str
    entitlement: str
    state: str
    deny_reason: Optional[str]
    pool: Optional[str]
    spill_hops: int
    priority: float


@dataclasses.dataclass
class ModeTrace:
    """One mode's full decision record for a scenario run."""

    label: str
    outcomes: dict            # request_id -> RequestOutcome
    flight_legs: dict         # request_id -> tuple[(pool, verdict, reason)]
    flight_priority: dict     # request_id -> tuple[float]


@dataclasses.dataclass
class ReplayResult:
    scenario: str
    traces: dict              # label -> ModeTrace
    mismatches: list          # human-readable diff lines
    @property
    def identical(self) -> bool:
        return not self.mismatches


def _close(a: float, b: float) -> bool:
    if a is None or b is None:
        return a is b
    return math.isclose(a, b, rel_tol=PRIORITY_RTOL,
                        abs_tol=PRIORITY_ATOL)


def capture_trace(sim, label: str) -> ModeTrace:
    """Extract the decision trace of a finished run: per-request
    terminal outcomes from the simulator plus the flight recorder's
    per-leg admission record."""
    outcomes = {}
    legs: dict = {}
    prios: dict = {}
    flight = sim.telemetry.flight if sim.telemetry is not None else None
    for rid, req in sim.requests.items():
        outcomes[rid] = RequestOutcome(
            request_id=rid, entitlement=req.entitlement,
            state=req.state.value,
            deny_reason=req.deny_reason, pool=req.pool,
            spill_hops=req.spill_hops,
            priority=float(req.priority or 0.0))
        if flight is not None:
            trace = flight.explain(rid)
            if trace is not None:
                legs[rid] = tuple(
                    (leg.pool, leg.verdict_name, leg.reason)
                    for leg in trace.legs)
                # priority is attributed only on ADMIT legs: the
                # scalar pipeline denies before computing one (records
                # 0.0) while the kernel always carries the row value —
                # a recorder representation difference, not a decision
                # difference
                prios[rid] = tuple(
                    float(leg.priority) if leg.verdict_name == "admit"
                    else None
                    for leg in trace.legs)
    return ModeTrace(label=label, outcomes=outcomes, flight_legs=legs,
                     flight_priority=prios)


def diff_traces(base: ModeTrace, other: ModeTrace,
                max_report: int = 20) -> list:
    """Human-readable decision diffs between two mode traces (empty
    list == decision-identical)."""
    out: list = []
    base_ids = set(base.outcomes)
    other_ids = set(other.outcomes)
    for rid in sorted(base_ids ^ other_ids):
        side = base.label if rid in base_ids else other.label
        out.append(f"{rid}: only present under {side}")
    for rid in sorted(base_ids & other_ids):
        a, b = base.outcomes[rid], other.outcomes[rid]
        for field in ("state", "deny_reason", "pool", "spill_hops"):
            va, vb = getattr(a, field), getattr(b, field)
            if va != vb:
                out.append(f"{rid}.{field}: {base.label}={va!r} "
                           f"{other.label}={vb!r}")
        if not _close(a.priority, b.priority):
            out.append(f"{rid}.priority: {base.label}={a.priority!r} "
                       f"{other.label}={b.priority!r}")
        la = base.flight_legs.get(rid)
        lb = other.flight_legs.get(rid)
        if la != lb:
            out.append(f"{rid}.flight: {base.label}={la!r} "
                       f"{other.label}={lb!r}")
        elif la is not None:
            pa = base.flight_priority[rid]
            pb = other.flight_priority[rid]
            if len(pa) != len(pb) or not all(
                    _close(x, y) for x, y in zip(pa, pb)):
                out.append(f"{rid}.flight_priority: "
                           f"{base.label}={pa!r} {other.label}={pb!r}")
        if len(out) >= max_report:
            out.append("... (diff truncated)")
            break
    return out


def run_replay(scenario: Scenario, duration_s: Optional[float] = None,
               modes=REPLAY_MODES) -> ReplayResult:
    """Execute ``scenario`` once per mode and diff every mode against
    the scalar baseline (the first entry of ``modes``)."""
    traces: dict = {}
    for label, admission_mode, fast in modes:
        sim = build_sim(scenario, admission_mode=admission_mode,
                        quantum_fast=fast, telemetry=True)
        sim.run(duration_s or scenario.duration_s)
        traces[label] = capture_trace(sim, label)
    labels = [m[0] for m in modes]
    base = traces[labels[0]]
    mismatches: list = []
    for label in labels[1:]:
        for line in diff_traces(base, traces[label]):
            mismatches.append(f"[{labels[0]} vs {label}] {line}")
    return ReplayResult(scenario=scenario.name, traces=traces,
                        mismatches=mismatches)
