"""Global invariant checkers over the live control plane.

Each checker inspects the WHOLE simulator after a completed quantum
(``scope == "step"``) or once at scenario end (``scope == "final"``)
and returns :class:`Violation` records.  Checkers read only public
surfaces — ``TokenPool.audit_snapshot()``, ``Ledger.level_audit``,
``Telemetry.slo`` — never private columns; the ``chaos-public-api``
analysis pass enforces this for the whole package.

The registry is class-based: :func:`default_checkers` instantiates a
fresh set per run so stateful checkers (drain-monotonicity keeps the
previous debt per entitlement) never leak state across scenarios.

Invariant catalog (the paper's conservation/§3.1 claims, made
executable):

==================== =====================================================
token-conservation   refills − charges + refunds == bucket level deltas,
                     per entitlement slot (``LevelAudit.drift`` == 0) and
                     in aggregate (``conservation_gap`` ≈ 0)
row-leaks            store/table free-list + live-row accounting closed
                     under churn; no unattributed settles
debt-bounds          debt ∈ [debt_min, debt_max] for debt-bearing
                     classes; |debt| non-increasing for debt-free classes
capacity             table-vs-store in-flight/resident recounts agree,
                     counters non-negative, resident ⊆ in-flight,
                     replicas ≤ max_replicas, backend lanes ≤ slots
mirror-coherence     cached device mirror byte-identical to host columns
                     (``mark_dirty`` discipline observable at runtime)
guaranteed-p99       guaranteed-tier P99 latency within the scenario's
                     Experiment-1 bound (final scope)
==================== =====================================================
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

import numpy as np

from repro.core.control_plane import CLASS_CODES
from repro.core.types import DEBT_CLASSES

#: absolute tolerance for float64 flow accounting
CONSERVATION_TOL = 1e-6
#: f32 column comparisons (debt EWMA et al.)
F32_EPS = 1e-5

#: class codes that may carry non-zero debt (Eq. 2 applies)
DEBT_CODES = frozenset(CLASS_CODES[sc] for sc in DEBT_CLASSES)


@dataclasses.dataclass(frozen=True)
class Violation:
    """One invariant breach at simulated time ``t``."""

    checker: str
    t: float
    pool: Optional[str]
    message: str

    def asdict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class CheckContext:
    """Everything a checker may read: the simulator, the instant, one
    ``audit_snapshot()`` per pool (computed once and shared across
    checkers), and the scenario for per-scenario bounds."""

    sim: Any
    now: float
    snaps: dict
    scenario: Any = None


class Checker:
    """Base invariant checker.  Subclasses set ``name`` /
    ``description``, pick a ``scope`` ("step" runs after every quantum
    via ``sim.step_hooks``; "final" runs once at scenario end), and
    implement :meth:`check`."""

    name = "base"
    scope = "step"
    description = ""

    def check(self, ctx: CheckContext) -> list[Violation]:
        raise NotImplementedError


CHECKER_CLASSES: list[type] = []


def register_checker(cls: type) -> type:
    CHECKER_CLASSES.append(cls)
    return cls


def default_checkers() -> list[Checker]:
    """Fresh instances of every registered checker (stateful checkers
    must not share state across runs)."""
    return [cls() for cls in CHECKER_CLASSES]


def make_context(sim, now: float, scenario=None) -> CheckContext:
    """Snapshot every pool once and wrap it for the checker set."""
    snaps = {name: pool.audit_snapshot()
             for name, pool in sim.manager.pools.items()}
    return CheckContext(sim=sim, now=now, snaps=snaps, scenario=scenario)


@register_checker
class TokenConservation(Checker):
    name = "token-conservation"
    description = ("bucket refills − charges + settle refunds fully "
                   "explain level deltas, per entitlement and in "
                   "aggregate")

    def check(self, ctx: CheckContext) -> list[Violation]:
        out: list[Violation] = []
        for pname, pool in ctx.sim.manager.pools.items():
            audit = pool.ledger.level_audit
            if audit is None:
                continue
            drift = audit.drift()
            bad = np.flatnonzero(np.abs(drift) > CONSERVATION_TOL)
            if bad.size:
                name_of = pool.store.name_of
                names = {int(s): (name_of[int(s)]
                                  if int(s) < len(name_of) else "?")
                         for s in bad[:4]}
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"unsanctioned bucket_level movement at slots "
                    f"{names} (max |drift| "
                    f"{float(np.abs(drift).max()):.3e})"))
            scale = abs(audit.baseline_total) \
                + sum(abs(v) for v in audit.flows.values())
            gap = audit.conservation_gap()
            if gap > CONSERVATION_TOL * max(1.0, scale):
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"flow ledger does not explain Σ bucket_level: "
                    f"gap {gap:.3e} over flows {audit.flows}"))
        return out


@register_checker
class RowLeaks(Checker):
    name = "row-leaks"
    description = ("ResidentStore/RequestTable free-list + live-row "
                   "accounting closed under churn; no unattributed "
                   "settles")

    def check(self, ctx: CheckContext) -> list[Violation]:
        out: list[Violation] = []
        for pname, snap in ctx.snaps.items():
            s, t = snap["store"], snap["table"]
            if s["live"] + s["free"] != s["capacity"]:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"store row leak: live {s['live']} + free "
                    f"{s['free']} != capacity {s['capacity']}"))
            if s["alive_rows"] != s["live"]:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"store alive column ({s['alive_rows']}) disagrees "
                    f"with slot map ({s['live']})"))
            if t["rows"] + t["free"] != t["capacity"]:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"table row leak: rows {t['rows']} + free "
                    f"{t['free']} != capacity {t['capacity']}"))
            if t["record_rows"] != t["records"]:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"table has_record column ({t['record_rows']}) "
                    f"disagrees with live records ({t['records']})"))
            if snap["unknown_settles"]:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"{snap['unknown_settles']} settles arrived for "
                    f"requests with no outstanding charge"))
        return out


@register_checker
class DebtBounds(Checker):
    name = "debt-bounds"
    description = ("debt within [debt_min, debt_max] for debt-bearing "
                   "classes; |debt| drain-monotone for debt-free "
                   "classes")

    def __init__(self) -> None:
        #: entitlement → |debt| at the previous check (debt-free
        #: classes only); survives migration because it is keyed by
        #: name, not (pool, slot)
        self._prev: dict[str, float] = {}

    def check(self, ctx: CheckContext) -> list[Violation]:
        out: list[Violation] = []
        for pname, snap in ctx.snaps.items():
            coeff = ctx.sim.manager.pool(pname).spec.coefficients
            debts = snap["debt_col"]
            codes = snap["class_code_col"]
            names = snap["alive_names"]
            low = debts < coeff.debt_min - F32_EPS
            high = debts > coeff.debt_max + F32_EPS
            for i in np.flatnonzero(low | high):
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"debt {debts[i]:.4f} of {names[i]!r} outside "
                    f"[{coeff.debt_min}, {coeff.debt_max}]"))
            for i, name in enumerate(names):
                if int(codes[i]) in DEBT_CODES:
                    continue
                mag = abs(float(debts[i]))
                prev = self._prev.get(name)
                if prev is not None and mag > prev + F32_EPS:
                    out.append(Violation(
                        self.name, ctx.now, pname,
                        f"debt-free class {names[i]!r} accrued debt: "
                        f"|debt| {mag:.4f} > previous {prev:.4f}"))
                self._prev[name] = mag
        return out


@register_checker
class Capacity(Checker):
    name = "capacity"
    description = ("in-flight/resident/KV accounting closed against "
                   "the request table; backend lanes never exceed "
                   "replica slots; fleet never exceeds max_replicas")

    def check(self, ctx: CheckContext) -> list[Violation]:
        out: list[Violation] = []
        for pname, snap in ctx.snaps.items():
            names = snap["alive_names"]
            for col in ("in_flight_col", "resident_col",
                        "kv_in_use_col"):
                neg = np.flatnonzero(snap[col] < 0)
                for i in neg:
                    out.append(Violation(
                        self.name, ctx.now, pname,
                        f"negative {col[:-4]} {snap[col][i]} for "
                        f"{names[i]!r}"))
            mism = np.flatnonzero(
                snap["in_flight_col"] != snap["per_slot_in_flight"])
            for i in mism:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"in_flight counter {snap['in_flight_col'][i]} for "
                    f"{names[i]!r} != table recount "
                    f"{snap['per_slot_in_flight'][i]}"))
            mism = np.flatnonzero(
                snap["resident_col"] != snap["per_slot_resident"])
            for i in mism:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"resident counter {snap['resident_col'][i]} for "
                    f"{names[i]!r} != table recount "
                    f"{snap['per_slot_resident'][i]}"))
            over = np.flatnonzero(
                snap["resident_col"] > snap["in_flight_col"])
            for i in over:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"resident {snap['resident_col'][i]} exceeds "
                    f"in-flight {snap['in_flight_col'][i]} for "
                    f"{names[i]!r}"))
            if snap["replicas"] > snap["max_replicas"]:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"{snap['replicas']} live replicas exceed ceiling "
                    f"{snap['max_replicas']}"))
            for r in ctx.sim.replicas.get(pname, ()):
                if r.load() > r.slots:
                    out.append(Violation(
                        self.name, ctx.now, pname,
                        f"replica {r.name} holds {r.load()} sequences "
                        f"over its {r.slots} slots"))
        return out


@register_checker
class MirrorCoherence(Checker):
    name = "mirror-coherence"
    description = ("cached device mirror matches host columns — any "
                   "host write without mark_dirty() shows as drift")

    def check(self, ctx: CheckContext) -> list[Violation]:
        out: list[Violation] = []
        for pname, snap in ctx.snaps.items():
            stale = {col: d for col, d in snap["mirror_drift"].items()
                     if d > 0.0}
            if stale:
                out.append(Violation(
                    self.name, ctx.now, pname,
                    f"device mirror stale for columns {stale} — host "
                    f"write bypassed mark_dirty()"))
        return out


@register_checker
class GuaranteedP99(Checker):
    name = "guaranteed-p99"
    scope = "final"
    description = ("guaranteed-tier P99 latency bounded per the "
                   "scenario's Experiment-1 budget")

    def check(self, ctx: CheckContext) -> list[Violation]:
        scenario = ctx.scenario
        if scenario is None or scenario.p99_bound_s is None:
            return []
        tel = ctx.sim.telemetry
        if tel is None:
            return []
        tier = tel.slo.snapshot().get("guaranteed")
        if not tier or not tier["completions"]:
            return []
        if tier["p99_s"] > scenario.p99_bound_s:
            return [Violation(
                self.name, ctx.now, None,
                f"guaranteed P99 {tier['p99_s']:.3f}s exceeds the "
                f"scenario bound {scenario.p99_bound_s:.3f}s "
                f"({tier['completions']} completions)")]
        return []
