"""Chaos scenario harness for the multi-tenant control plane.

Three layers:

- :mod:`repro.chaos.scenario` — the declarative, seeded scenario DSL
  (:class:`Scenario` / :class:`ScenarioEvent`) and ``build_sim``.
- :mod:`repro.chaos.invariants` — the global invariant-checker
  registry run after every simulated quantum (token conservation, row
  leaks, debt bounds, capacity closure, mirror coherence, guaranteed
  P99).
- :mod:`repro.chaos.replay` — differential replay: the same seeded
  scenario under scalar / quantum / fast-path admission must be
  decision-identical.

``repro.chaos.scenarios`` ships the library of scripted incidents and
``repro.chaos.runner`` executes a scenario under the full registry.
"""
from repro.chaos.invariants import (
    CheckContext,
    Checker,
    Violation,
    default_checkers,
    make_context,
    register_checker,
)
from repro.chaos.replay import (
    REPLAY_MODES,
    ModeTrace,
    ReplayResult,
    RequestOutcome,
    capture_trace,
    diff_traces,
    run_replay,
)
from repro.chaos.runner import checker_catalog, install_checkers, run_scenario
from repro.chaos.scenario import (
    Scenario,
    ScenarioEvent,
    build_sim,
    schedule_event,
    seeded_backoff,
)
from repro.chaos.scenarios import SCENARIOS, by_name

__all__ = [
    "CheckContext", "Checker", "Violation", "default_checkers",
    "make_context", "register_checker",
    "REPLAY_MODES", "ModeTrace", "ReplayResult", "RequestOutcome",
    "capture_trace", "diff_traces", "run_replay",
    "checker_catalog", "install_checkers", "run_scenario",
    "Scenario", "ScenarioEvent", "build_sim", "schedule_event",
    "seeded_backoff",
    "SCENARIOS", "by_name",
]
