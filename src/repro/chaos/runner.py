"""Scenario runner: build → instrument → run → report.

``run_scenario`` wires the invariant registry into the simulator's
``step_hooks`` (every checker sees the control plane after EVERY
completed quantum — post-settle, post-tick), enables the ledger's
conservation audit on every pool, executes the scripted timeline and
returns a JSON-serializable report: violations, SLO snapshot, incident
windows and per-workload outcome counts.  The benchmark entry point
(``benchmarks/chaos_scenarios.py``) aggregates these into
``SCENARIO_report.json``.
"""
from __future__ import annotations

import itertools
from typing import Optional

from repro.chaos.invariants import (
    Checker,
    default_checkers,
    make_context,
)
from repro.chaos.scenario import Scenario, build_sim


def install_checkers(sim, checkers: list, violations: list,
                     scenario: Optional[Scenario] = None,
                     check_interval_steps: int = 1) -> None:
    """Register the step-scope checkers on ``sim.step_hooks``.

    One shared ``audit_snapshot()`` per pool per checked step; the
    interval lets long soak runs trade cadence for wall-clock (1 =
    every quantum)."""
    step_checkers = [c for c in checkers if c.scope == "step"]
    if not step_checkers:
        return
    counter = itertools.count()

    def hook(sim, now: float) -> None:
        if next(counter) % check_interval_steps:
            return
        ctx = make_context(sim, now, scenario)
        for checker in step_checkers:
            violations.extend(checker.check(ctx))

    sim.step_hooks.append(hook)


def run_scenario(scenario: Scenario, admission_mode: str = "quantum",
                 quantum_fast: bool = True,
                 checkers: Optional[list] = None,
                 check_interval_steps: int = 1) -> dict:
    """Execute one scenario under the full invariant registry."""
    sim = build_sim(scenario, admission_mode=admission_mode,
                    quantum_fast=quantum_fast, telemetry=True)
    for pool in sim.manager.pools.values():
        pool.ledger.enable_level_audit()
    if checkers is None:
        checkers = default_checkers()
    violations: list = []
    install_checkers(sim, checkers, violations, scenario,
                     check_interval_steps)
    summary = sim.run(scenario.duration_s)

    final_ctx = make_context(sim, scenario.duration_s, scenario)
    for checker in checkers:
        if checker.scope == "final":
            violations.extend(checker.check(final_ctx))

    tel = sim.telemetry
    per_workload = {
        name: {k: v for k, v in stats.items()
               if isinstance(v, (int, float, dict))}
        for name, stats in summary["per_workload"].items()}
    return {
        "scenario": scenario.name,
        "description": scenario.description,
        "seed": scenario.seed,
        "duration_s": scenario.duration_s,
        "admission_mode": admission_mode,
        "quantum_fast": quantum_fast,
        "p99_bound_s": scenario.p99_bound_s,
        "checkers": [{"name": c.name, "scope": c.scope,
                      "description": c.description} for c in checkers],
        "violations": [v.asdict() for v in violations],
        "passed": not violations,
        "per_workload": per_workload,
        "slo": tel.slo.snapshot() if tel is not None else {},
        "incident_windows": (tel.incident_windows()
                             if tel is not None else []),
        "requests_total": len(sim.requests),
    }


def checker_catalog(checkers: Optional[list] = None) -> list:
    """Name/scope/description rows for docs and reports."""
    if checkers is None:
        checkers = default_checkers()
    return [{"name": c.name, "scope": c.scope,
             "description": c.description} for c in checkers]
