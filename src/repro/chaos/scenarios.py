"""Library of scripted incident scenarios (the paper's Experiments
1–3 failure modes, condensed to seconds-long seeded timelines).

Every scenario keeps the replay-parity contract of
``Gateway.handle_quantum``: workload routes are single-leg or share
one common pool order, so the scalar, quantum and fast-path pipelines
must be decision-identical — :mod:`repro.chaos.replay` asserts it.

All five run with every invariant checker enabled and a bounded
guaranteed-tier P99 (``p99_bound_s``); sizes are deliberately small
(seconds of simulated time, single-digit replica fleets) so the whole
suite stays test-runnable while still driving failure, retry-storm,
surge, drain and churn paths through the real control plane.
"""
from __future__ import annotations

from repro.core import ServiceClass
from repro.chaos.scenario import Scenario, ScenarioEvent


def _wl(name: str, sc: ServiceClass, slots: float, slo_ms: float,
        rate: float, pools: tuple, retries: int = 1, **kw) -> dict:
    kw.update(name=name, service_class=sc, slots=slots, slo_ms=slo_ms,
              rate_rps=rate, pools=pools, max_retries=retries,
              in_tokens=32, out_tokens=32)
    return kw


CORRELATED_FAILURE = Scenario(
    name="correlated_failure",
    description=("both replicas of the preferred pool die 0.4s apart "
                 "mid-traffic; guaranteed traffic must ride out the "
                 "outage on the spill pool until staggered recovery"),
    seed=11, duration_s=10.0, p99_bound_s=6.0,
    sites=(
        dict(name="east", n_replicas=2, replica_slots=8,
             replica_tps=160.0),
        dict(name="west", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
    ),
    workloads=(
        _wl("gold", ServiceClass.GUARANTEED, 4, 800.0, 2.0,
            ("east", "west"), retries=2),
        _wl("flex", ServiceClass.ELASTIC, 4, 2000.0, 5.0,
            ("east", "west")),
    ),
    events=(
        ScenarioEvent(3.0, "fail_replica", dict(pool="east", idx=0)),
        ScenarioEvent(3.4, "fail_replica", dict(pool="east", idx=1)),
        ScenarioEvent(6.0, "recover_replica", dict(pool="east", idx=0)),
        ScenarioEvent(6.6, "recover_replica", dict(pool="east", idx=1)),
    ),
)


RETRY_STORM = Scenario(
    name="retry_storm",
    description=("an elastic tenant floods a single pool at several "
                 "times its entitlement with aggressive client "
                 "retries; denied keys re-submit on jittered backoff "
                 "(thundering herd) while the guaranteed tenant must "
                 "stay inside its latency budget"),
    seed=23, duration_s=10.0, p99_bound_s=6.0,
    retry_base_s=0.2, retry_jitter_s=0.6,
    sites=(
        dict(name="core", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
    ),
    workloads=(
        _wl("gold", ServiceClass.GUARANTEED, 4, 800.0, 1.5, ("core",),
            retries=2),
        _wl("burst", ServiceClass.ELASTIC, 3, 2000.0, 12.0, ("core",),
            retries=4),
    ),
    events=(),
)


SURGE_FLAP = Scenario(
    name="surge_flap",
    description=("elastic demand flaps between idle and several times "
                 "pool capacity every two seconds; admission must "
                 "track the square wave without leaking rows or debt"),
    seed=37, duration_s=12.0, p99_bound_s=6.0,
    sites=(
        dict(name="east", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
        dict(name="west", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
    ),
    workloads=(
        _wl("gold", ServiceClass.GUARANTEED, 4, 800.0, 2.0,
            ("east", "west"), retries=2),
        _wl("surge", ServiceClass.ELASTIC, 3, 2000.0, 2.0,
            ("east", "west")),
    ),
    events=(
        ScenarioEvent(2.0, "set_rate", dict(workload="surge", rate=18.0)),
        ScenarioEvent(4.0, "set_rate", dict(workload="surge", rate=1.0)),
        ScenarioEvent(6.0, "set_rate", dict(workload="surge", rate=20.0)),
        ScenarioEvent(8.0, "set_rate", dict(workload="surge", rate=2.0)),
    ),
)


SLOW_DRAIN = Scenario(
    name="slow_drain",
    description=("demand collapses under an autoscaled fleet — the "
                 "planner drains surplus replicas (no new dispatch, "
                 "residuals finish) — then surges back through the "
                 "provisioning lag"),
    seed=41, duration_s=16.0, p99_bound_s=6.0,
    autoscale=True, provision_lag_s=1.0, drain_s=1.5,
    sites=(
        dict(name="core", n_replicas=3, replica_slots=8,
             replica_tps=160.0, max_replicas=3),
    ),
    workloads=(
        _wl("gold", ServiceClass.GUARANTEED, 4, 800.0, 2.0, ("core",),
            retries=2),
        _wl("batch", ServiceClass.ELASTIC, 6, 2000.0, 8.0, ("core",)),
    ),
    events=(
        ScenarioEvent(4.0, "set_rate", dict(workload="batch", rate=1.0)),
        ScenarioEvent(10.0, "set_rate", dict(workload="batch", rate=8.0)),
    ),
)


CHURN_MIGRATION = Scenario(
    name="churn_migration",
    description=("standby entitlements join, migrate across pools and "
                 "leave while live traffic runs and a replica fails — "
                 "store rows, bucket levels and debt must survive the "
                 "churn without leaks"),
    seed=53, duration_s=12.0, p99_bound_s=6.0,
    sites=(
        dict(name="east", n_replicas=2, replica_slots=8,
             replica_tps=160.0),
        dict(name="west", n_replicas=1, replica_slots=8,
             replica_tps=160.0),
    ),
    workloads=(
        _wl("gold", ServiceClass.GUARANTEED, 4, 800.0, 2.0,
            ("east", "west"), retries=2),
        _wl("flex", ServiceClass.ELASTIC, 4, 2000.0, 5.0,
            ("east", "west")),
    ),
    events=(
        ScenarioEvent(2.0, "add_entitlement", dict(
            pool="east", name="standby-a",
            service_class=ServiceClass.GUARANTEED,
            slo_ms=1000.0, tokens_per_second=40.0, slots=1.0)),
        ScenarioEvent(2.5, "add_entitlement", dict(
            pool="east", name="standby-b",
            service_class=ServiceClass.ELASTIC,
            slo_ms=2000.0, tokens_per_second=30.0, slots=1.0)),
        ScenarioEvent(4.0, "migrate", dict(
            entitlement="standby-a", src="east", dst="west")),
        ScenarioEvent(5.0, "fail_replica", dict(pool="west", idx=0)),
        ScenarioEvent(6.0, "remove_entitlement", dict(
            pool="east", name="standby-b")),
        ScenarioEvent(7.0, "recover_replica", dict(pool="west", idx=0)),
        ScenarioEvent(8.0, "remove_entitlement", dict(
            pool="west", name="standby-a")),
    ),
)


#: the library, in documentation order
SCENARIOS: tuple = (
    CORRELATED_FAILURE,
    RETRY_STORM,
    SURGE_FLAP,
    SLOW_DRAIN,
    CHURN_MIGRATION,
)


def by_name(name: str) -> Scenario:
    for s in SCENARIOS:
        if s.name == name:
            return s
    raise KeyError(name)
